"""Fleet-scale control plane: sharded KV namespace, array-native
liveness, queue-cursor drains — and the proof that none of it changed
observable semantics.

The load-bearing property (ISSUE 9): replaying identical scenario
traces through the legacy flat-dict store (scan+sort drains) and the
sharded store (queue-cursor drains, HeartbeatTable liveness) produces
byte-equal ``LoopEvent`` streams and identical plans; the sharded path
just does O(events) work instead of O(store) per tick (``tick_stats``-
asserted here, throughput-asserted in ``bench_controlplane``).
"""
import random

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.agent import UnicronAgent, heartbeat_cohort
from repro.core.chaos import ChaosHarness, WorldEvent, demo_world
from repro.core.cluster import Cluster
from repro.core.controlloop import ControlLoop
from repro.core.coordinator import UnicronCoordinator
from repro.core.costmodel import A800, TaskModel
from repro.core.detection import ErrorKind, FleetMonitor, HeartbeatTable
from repro.core.handling import Action
from repro.core.kvstore import (CONSUMED_PREFIX, CURSOR_PREFIX, KVStore,
                                LegacyKVStore, QUEUE_FAMILIES)
from repro.core.waf import Task


def _task(size: str, weight: float) -> Task:
    return Task(model=TaskModel.from_arch(get_arch(size), global_batch=128),
                weight=weight)


def _fleet():
    tasks = [_task("gpt3-1.3b", 2.0), _task("gpt3-7b", 1.4),
             _task("gpt3-1.3b", 1.0)]
    return tasks, [8, 8, 4], _task("gpt3-1.3b", 0.7)


def _stack(kv_cls, n_nodes=6, gpus=4):
    tasks, assignment, _ = _fleet()
    kv = kv_cls()
    coord = UnicronCoordinator(list(tasks), list(assignment), A800, kv=kv,
                               n_cluster_workers=n_nodes * gpus,
                               workers_per_node=gpus)
    cluster = Cluster(n_nodes, gpus)
    cluster.assign(list(assignment))
    agents = {i: UnicronAgent(i, kv, n_gpus=gpus, seed=100 + i)
              for i in range(n_nodes)}
    loop = ControlLoop(coord, cluster, agents)
    return kv, coord, cluster, agents, loop


def _event_sig(events):
    """The observable decision stream: wall-clock latency fields and
    cumulative engine counters excluded (they measure the machine, not
    the decision)."""
    return [(e.time, e.node, e.kind, e.action, e.plan) for e in events]


# ---------------------------------------------------------------------------
# Tentpole: legacy-vs-sharded equivalence on the scenario suite
# ---------------------------------------------------------------------------


def _rich_world(tasks, launch_a, launch_b):
    """Denser than ``demo_world``: simultaneous kills, simultaneous
    launches, an in-band SEV1 (ECC) drain, and staggered repairs."""
    return [
        WorldEvent(40.0, "error", node=1, error=ErrorKind.CUDA_ERROR),
        WorldEvent(40.0, "error", node=4, error=ErrorKind.NCCL_TIMEOUT),
        WorldEvent(220.0, "kill", node=2),
        WorldEvent(220.0, "kill", node=5),
        WorldEvent(400.0, "finish", task=tasks[2]),
        WorldEvent(580.0, "launch", task=launch_a, avg_iter_s=12.0),
        WorldEvent(580.0, "launch", task=launch_b, avg_iter_s=20.0),
        WorldEvent(760.0, "repair", node=2),
        WorldEvent(940.0, "error", node=0, error=ErrorKind.ECC_ERROR),
        WorldEvent(1120.0, "repair", node=5),
    ]


@pytest.mark.parametrize("world_name", ["demo", "rich"])
def test_legacy_vs_sharded_equivalence(world_name):
    """Identical traces through both stores: byte-equal event streams,
    identical plans, identical final state."""
    results, streams = {}, {}
    for kv_cls in (LegacyKVStore, KVStore):
        tasks, assignment, launch = _fleet()
        if world_name == "demo":
            world = demo_world(tasks[2], launch)
            until = 1100.0
        else:
            world = _rich_world(tasks, launch, _task("gpt3-1.3b", 0.5))
            until = 1400.0
        h = ChaosHarness(tasks=tasks, assignment=assignment, hw=A800,
                         kv_factory=kv_cls)
        results[kv_cls] = h.run(world, until=until)
        streams[kv_cls] = _event_sig(h.events)
        if kv_cls is KVStore:
            # the sharded run was genuinely event-driven: the only
            # prefix scans were the amortized marker GC sweeps
            assert h.loop._queued
            st = h.loop.tick_stats
            assert st["prefix_scans"] == st["gc_runs"]
            assert st["queue_reads"] > 0
        else:
            assert not h.loop._queued
    assert streams[LegacyKVStore] == streams[KVStore]
    assert any(ev[4] is not None for ev in streams[KVStore])
    legacy, sharded = results[LegacyKVStore], results[KVStore]
    assert legacy.assignment == sharded.assignment
    assert legacy.waf == sharded.waf
    assert legacy.healthy_workers == sharded.healthy_workers
    assert legacy.n_events == sharded.n_events


def test_randomized_stream_equivalence():
    """Seeded randomized op stream — reports with mixed detection
    latencies, churn with stale epochs, duplicate re-deliveries —
    replayed through both stores tick by tick."""
    stacks = {cls: _stack(cls) for cls in (LegacyKVStore, KVStore)}
    rng = random.Random(42)
    extra = [_task("gpt3-1.3b", 0.5), _task("gpt3-1.3b", 0.9)]
    script = []
    for step in range(120):
        t = 10.0 * step
        roll = rng.random()
        if roll < 0.35:
            script.append(("error", rng.randrange(6),
                           rng.choice([ErrorKind.NCCL_TIMEOUT,
                                       ErrorKind.CUDA_ERROR,
                                       ErrorKind.CONNECTION_REFUSED]), t))
        elif roll < 0.45:
            script.append(("finish", rng.randrange(6), rng.randrange(3), t))
        elif roll < 0.55:
            script.append(("launch", rng.randrange(6),
                           rng.randrange(len(extra)), t))
        elif roll < 0.7:
            script.append(("dup", t))
        script.append(("tick", t + rng.choice([1.0, 5.0, 9.0])))
    sigs = {}
    for cls, (kv, coord, cluster, agents, loop) in stacks.items():
        consumed_once = {}
        for op in script:
            if op[0] == "error":
                _, node, kind, t = op
                agents[node].report(kind, t)
            elif op[0] == "finish":
                _, node, idx, t = op
                if idx < len(coord.entries):
                    agents[node].report_task_finished(idx, t,
                                                      coord.plan_epoch)
            elif op[0] == "launch":
                _, node, which, t = op
                if all(e.task is not extra[which] for e in coord.entries):
                    agents[node].request_task_launch(extra[which], t,
                                                     coord.plan_epoch)
            elif op[0] == "dup":
                if consumed_once:
                    key, rec = next(iter(consumed_once.items()))
                    kv.put(key, rec, now=op[1])    # late re-delivery
            else:
                _, t = op
                for rec_key, rec in kv.prefix("/errors/").items():
                    consumed_once.setdefault(rec_key, rec)
                loop.tick(t)
        sigs[cls] = _event_sig(loop.events)
        assert len(loop.events) > 10
    assert sigs[LegacyKVStore] == sigs[KVStore]
    assert ([e.n_workers for e in stacks[LegacyKVStore][1].entries]
            == [e.n_workers for e in stacks[KVStore][1].entries])


# ---------------------------------------------------------------------------
# Queue-cursor drains
# ---------------------------------------------------------------------------


def test_cursor_blocks_on_invisible_record_and_persists():
    """The cursor never passes a record still waiting out its detection
    latency, and a restarted loop resumes from the persisted cursor
    without double-firing."""
    kv, coord, cluster, agents, loop = _stack(KVStore)
    agents[1].report(ErrorKind.NCCL_TIMEOUT, 0.0)   # visible at ~90s
    agents[2].report(ErrorKind.CUDA_ERROR, 0.0)     # visible at 0.3s
    evs = loop.tick(1.0)
    assert [e.kind for e in evs] == [ErrorKind.CUDA_ERROR]
    # the NCCL report heads the queue unresolved: cursor must not move
    assert kv.get(CURSOR_PREFIX + "/errors/", 0) == 0
    # loop crashes; the successor inherits cursor + markers from the KV
    loop2 = ControlLoop(coord, cluster, agents)
    evs = loop2.tick(95.0)
    assert [e.kind for e in evs] == [ErrorKind.NCCL_TIMEOUT]
    assert kv.get(CURSOR_PREFIX + "/errors/") == 2
    assert loop2.tick(96.0) == []                   # nothing re-fires
    assert kv.prefix("/errors/") == {}


def test_queue_compaction_below_cursor():
    """Entries below the persisted cursor are compacted away — the queue
    holds the in-flight window, not history."""
    kv = KVStore()
    for i in range(50):
        kv.put(f"/errors/1/{i}.000", {"visible_at": 0.0}, now=float(i))
    assert kv.queue_len("/errors/") == 50
    assert len(kv.queue_slice("/errors/", 48)) == 2
    assert len(kv._qlog["/errors/"]) == 2           # compacted
    assert kv.queue_len("/errors/") == 50           # monotonic index


def test_quiet_tick_is_free_on_sharded_store():
    """The event-driven guarantee: a tick with empty queues does zero
    prefix scans, zero queue reads and zero sort allocations."""
    kv, coord, cluster, agents, loop = _stack(KVStore)
    for a in agents.values():
        a.heartbeat(0.0)
    loop.tick(1.0)                     # first tick runs the initial GC
    before = dict(loop.tick_stats)
    for a in agents.values():
        a.heartbeat(2.0)
    assert loop.tick(3.0) == []
    assert loop.tick(4.0) == []
    assert loop.tick_stats["prefix_scans"] == before["prefix_scans"]
    assert loop.tick_stats["queue_reads"] == before["queue_reads"]
    assert loop.tick_stats["drain_sorts"] == before["drain_sorts"]
    # one event -> exactly one queue read, and GC stays amortized
    agents[2].report(ErrorKind.CUDA_ERROR, 4.0)
    assert len(loop.tick(5.0)) == 1
    assert loop.tick_stats["queue_reads"] == before["queue_reads"] + 1
    assert loop.tick_stats["gc_runs"] == 1
    loop.tick(100.0)                   # interval elapsed -> GC sweeps
    assert loop.tick_stats["gc_runs"] == 2


def test_quiet_tick_skips_sort_on_legacy_store():
    """Scan-fallback satellite: empty families short-circuit before the
    per-tick ``sorted()`` allocation."""
    kv, coord, cluster, agents, loop = _stack(LegacyKVStore)
    loop.tick(1.0)
    assert loop.tick_stats["prefix_scans"] > 0      # scans are unavoidable
    assert loop.tick_stats["drain_sorts"] == 0      # but sorts aren't
    agents[2].report(ErrorKind.CUDA_ERROR, 1.0)
    assert len(loop.tick(2.0)) == 1
    assert loop.tick_stats["drain_sorts"] == 1


# ---------------------------------------------------------------------------
# Sharded-store contracts
# ---------------------------------------------------------------------------


def test_prefix_is_namespace_scoped():
    kv = KVStore()
    kv.put("/errors/1/10.000", "a")
    kv.put("/errors/1025/10.000", "b")              # different node group
    kv.put("/errors/5000/10.000", "c")
    kv.put("/tasks/finished/10.000/1", "d")
    kv.put("/coord/journal/tasks", "e")
    kv.put("/unregistered/x", "f")                  # catch-all shard
    kv.put("/nodes/7/alive", 3.0, ttl=6.0, now=3.0)
    assert kv.prefix("/errors/1025/") == {"/errors/1025/10.000": "b"}
    assert set(kv.prefix("/errors/")) == {"/errors/1/10.000",
                                          "/errors/1025/10.000",
                                          "/errors/5000/10.000"}
    assert kv.prefix("/nodes/") == {"/nodes/7/alive": 3.0}
    assert kv.prefix("/nodes/7/") == {"/nodes/7/alive": 3.0}
    assert kv.prefix("/unreg") == {"/unregistered/x": "f"}
    assert len(kv.prefix("/")) == 7
    assert len(kv.prefix("")) == 7
    kv.delete("/errors/1025/10.000")
    assert kv.get("/errors/1025/10.000") is None
    assert len(kv.prefix("/errors/")) == 2


def test_cas_ttl_interplay_on_sharded_buckets():
    """The PR 6 lease-wipe regression, re-run against sharded buckets:
    cas swaps the value only, on heartbeat keys AND ordinary bucketed
    keys — the lease must survive and fire on schedule."""
    kv = KVStore()
    kv.put("/nodes/2049/alive", 10.0, ttl=6.0, now=10.0)   # group 2
    assert kv.cas("/nodes/2049/alive", 10.0, 11.0)
    assert kv.get("/nodes/2049/alive") == 11.0
    assert kv.expire(15.9) == []
    assert kv.expire(16.0) == ["/nodes/2049/alive"]
    kv.put("/errors/9000/x", 1, ttl=5.0, now=0.0)          # ledger lease
    assert kv.cas("/errors/9000/x", 1, 2)
    assert kv.get("/errors/9000/x") == 2
    assert kv.expire(4.9) == []
    assert kv.expire(5.0) == ["/errors/9000/x"]
    assert kv.get("/errors/9000/x") is None
    # a ttl-free overwrite clears a previous lease (legacy semantics)
    kv.put("/errors/9000/y", 1, ttl=5.0, now=0.0)
    kv.put("/errors/9000/y", 2)
    assert kv.expire(100.0) == []
    assert kv.get("/errors/9000/y") == 2


def test_watch_fires_across_shards():
    kv = KVStore()
    seen = []
    kv.watch("/errors/", lambda op, k, v: seen.append((op, k)))
    kv.watch("/nodes/", lambda op, k, v: seen.append((op, k)))
    kv.put("/errors/1/a", 1)
    kv.put("/errors/5000/b", 2)                     # different group bucket
    kv.put("/tasks/finished/1/1", 3)                # not watched
    kv.heartbeat_batch([3, 2050], 1.0, ttl=6.0)     # watched: per-key notify
    kv.delete("/errors/1/a")
    kv.expire(10.0)                                 # both heartbeats lapse
    assert seen == [("put", "/errors/1/a"), ("put", "/errors/5000/b"),
                    ("put", "/nodes/3/alive"), ("put", "/nodes/2050/alive"),
                    ("delete", "/errors/1/a"),
                    ("expire", "/nodes/2050/alive"),
                    ("expire", "/nodes/3/alive")]


def test_recover_reads_sharded_journal_namespace():
    """Coordinator journals land in the ``/coord/journal/`` shard and
    ``UnicronCoordinator.recover`` rebuilds from there."""
    tasks, assignment, launch = _fleet()
    kv = KVStore()
    coord = UnicronCoordinator(list(tasks), list(assignment), A800, kv=kv,
                               n_cluster_workers=24, workers_per_node=4)
    coord.task_launched(launch, 20, avg_iter_s=12.0)
    journal_shard = kv._shards["/coord/journal/"]
    assert sum(len(b.data) for b in journal_shard.values()) >= 3
    back = UnicronCoordinator.recover(kv, A800, n_cluster_workers=24,
                                      workers_per_node=4)
    assert ([e.n_workers for e in back.entries]
            == [e.n_workers for e in coord.entries])
    assert back.plan_epoch == coord.plan_epoch


def test_heartbeat_batch_equals_individual_puts():
    batched, single = KVStore(), KVStore()
    ids = [0, 5, 1023, 1024, 90000]
    batched.heartbeat_batch(ids, 7.0, ttl=6.0)
    for i in ids:
        single.put(f"/nodes/{i}/alive", 7.0, ttl=6.0, now=7.0)
    for i in ids:
        assert batched.get(f"/nodes/{i}/alive") == 7.0
    assert batched.prefix("/nodes/") == single.prefix("/nodes/")
    assert batched.expire(13.0) == single.expire(13.0)
    assert batched.prefix("/nodes/") == {}


def test_heartbeat_cohort_batches_per_store():
    kv = KVStore()
    agents = {i: UnicronAgent(i, kv, n_gpus=4) for i in range(8)}
    agents[3].kill()
    heartbeat_cohort(agents, 5.0)
    assert kv.get("/nodes/3/alive") is None         # dead: no beat
    assert all(kv.get(f"/nodes/{i}/alive") == 5.0
               for i in range(8) if i != 3)
    # legacy stores take the per-agent path transparently
    lkv = LegacyKVStore()
    lagents = {i: UnicronAgent(i, lkv, n_gpus=4) for i in range(4)}
    heartbeat_cohort(lagents, 5.0)
    assert all(lkv.get(f"/nodes/{i}/alive") == 5.0 for i in range(4))


# ---------------------------------------------------------------------------
# HeartbeatTable (array-native liveness)
# ---------------------------------------------------------------------------


def test_heartbeat_table_across_groups():
    hb = HeartbeatTable(group_size=4)
    hb.beat(1, 10.0, deadline=16.0)
    hb.beat_batch([2, 3, 4, 9], 11.0, deadline=17.0)   # spans groups 0-2
    assert len(hb) == 5
    assert hb.get(1) == 10.0 and hb.get(9) == 11.0
    assert hb.get(5) is None
    assert dict(hb.items()) == {1: 10.0, 2: 11.0, 3: 11.0,
                                4: 11.0, 9: 11.0}
    # vectorized expiry: ascending ids, exactly once
    assert hb.expired(16.0) == [1]
    assert hb.expired(16.0) == []
    assert hb.expired(17.0) == [2, 3, 4, 9]
    assert len(hb) == 0


def test_heartbeat_table_pop_and_cas():
    hb = HeartbeatTable(group_size=4)
    hb.beat(6, 1.0, deadline=9.0)
    assert hb.cas(6, 1.0, 2.0)                      # value swap
    assert not hb.cas(6, 1.0, 3.0)                  # stale expect
    assert hb.get(6) == 2.0
    assert hb.expired(8.9) == []                    # deadline survived cas
    assert hb.pop(6) and not hb.pop(6)
    assert hb.get(6) is None
    assert hb.cas(7, None, 5.0)                     # expected-absent insert
    assert hb.get(7) == 5.0
    assert hb.expired(1e12) == []                   # insert carries no lease


# ---------------------------------------------------------------------------
# Satellite: FleetMonitor geometric growth
# ---------------------------------------------------------------------------


def test_fleetmonitor_grow_geometric_doubling():
    """Growth is amortized (capacity doubles) and observable behavior —
    observe / averages / statuses — is unchanged vs a monitor primed
    with the full task set up front."""
    grown = FleetMonitor.primed([10.0, 20.0], window=8)
    avgs = [10.0, 20.0]
    caps = {grown.capacity}
    for i in range(30):
        avg = 5.0 + i
        assert grown.grow(avg) == 2 + i
        avgs.append(avg)
        caps.add(grown.capacity)
    eager = FleetMonitor.primed(avgs, window=8)
    assert grown.n_tasks == eager.n_tasks == 32
    # a handful of geometric realloc points, not one per grow
    assert len(caps) <= 6
    assert grown.capacity >= grown.n_tasks
    rng = np.random.default_rng(0)
    for _ in range(20):
        tasks = rng.choice(32, size=8, replace=False)
        vals = rng.uniform(1.0, 40.0, size=8)
        grown.observe(tasks, vals)
        eager.observe(tasks, vals)
    np.testing.assert_array_equal(grown.averages(), eager.averages())
    np.testing.assert_array_equal(grown.statuses(range(32), 30.0),
                                  eager.statuses(range(32), 30.0))


# ---------------------------------------------------------------------------
# Chaos parity for the sharded liveness path (detail asserts; the full
# suite parity lives in test_chaos.py)
# ---------------------------------------------------------------------------


def test_sharded_harness_uses_heartbeat_table():
    tasks, assignment, launch = _fleet()
    h = ChaosHarness(tasks=tasks, assignment=assignment, hw=A800)
    assert isinstance(h.kv, KVStore)
    h.run(demo_world(tasks[2], launch), until=200.0)
    assert len(h.kv._heartbeats) > 0                # liveness is array-native
    assert h.loop._queued


def test_marker_gc_still_bounds_residency_with_interval():
    """Amortized GC keeps residency O(retention + interval), and every
    report still fires exactly once."""
    kv, coord, cluster, agents, loop = _stack(KVStore)
    for i in range(200):
        t = 50.0 * i
        agents[i % 6].report(ErrorKind.NCCL_TIMEOUT, t)
        loop.tick(t + 40.0)
    loop.tick(10200.0)
    assert kv.prefix("/errors/") == {}
    n_markers = len(kv.prefix(CONSUMED_PREFIX))
    assert n_markers <= (600.0 + loop.gc_interval_s) / 50.0 + 2
    assert len(loop.events) == 200
    assert loop.tick_stats["gc_runs"] < loop.tick_stats["ticks"]


def test_all_families_have_queues():
    kv = KVStore()
    assert QUEUE_FAMILIES == ("/errors/", "/tasks/finished/",
                              "/tasks/launch/")
    for fam in QUEUE_FAMILIES:
        assert kv.queue_len(fam) == 0
    kv.put("/tasks/launch/00000000000010.000/1/1", {"visible_at": 10.0})
    kv.put("/tasks/finished/10.000/1", {"visible_at": 10.0})
    assert kv.queue_len("/tasks/launch/") == 1
    assert kv.queue_len("/tasks/finished/") == 1
