"""Max-plus convolution kernel microbenchmark (the planner's DP floor).

Per-convolution latency at n in {256, 1024, 4096} for the four kernels:

  * numpy   — ``_maxplus_vals`` (plain windowed matrix, PR-1 baseline);
  * fused   — ``_maxplus_vals_fused`` dense (tiled add+max, no (n x n)
              candidate matrix);
  * banded  — ``_maxplus_vals_fused`` at band = cap (cap = n/8, the
              ``Task.max_workers`` regime);
  * pallas  — ``kernels.maxplus.maxplus_conv`` in interpret mode (f32;
              the compiled Mosaic path needs a TPU).

Hard asserts (the harness fails loudly on a regression):

  * fused and banded outputs are bitwise identical to ``_maxplus_vals``
    on their candidate sets; pallas matches the f32 oracle to 1e-6;
  * at n >= 1024 and cap = n/8 the banded kernel is >= 5x faster than
    the dense convolution the engines previously always ran
    (``_maxplus_vals``) — the acceptance floor.  ``banded_vs_fused``
    (banded against the *new* dense fused kernel) is also emitted; it
    sits near the 8x candidate-count ratio minus memory-system effects.

``REPRO_BENCH_QUICK=1`` (set by ``run.py --quick``) trims the grid to
{256, 1024} for CI smoke runs.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.planner import _maxplus_vals, _maxplus_vals_fused

GRID_N = [256, 1024, 4096]
CAP_DIV = 8                    # banded regime: cap = n / 8
BANDED_FLOOR = 5.0             # banded >= 5x dense at cap <= n/8, n >= 1024
PALLAS_TOL = 1e-6


def _data(n: int, cap: int):
    """Monotone DP vector + reward row flat past the cap (the band
    contract the planner guarantees)."""
    rng = np.random.RandomState(n)
    prev = np.maximum.accumulate(rng.uniform(0.0, 100.0, n + 1))
    g = rng.uniform(0.0, 100.0, n + 1)
    g[cap:] = g[cap]
    return prev, g


def run() -> list:
    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    grid = [256, 1024] if quick else GRID_N
    iters = 3 if quick else 7
    rows = []
    checked_floor = False
    for n in grid:
        cap = n // CAP_DIV
        prev, g = _data(n, cap)

        want = _maxplus_vals(prev, g)
        assert np.array_equal(want, _maxplus_vals_fused(prev, g)), n
        assert np.array_equal(want,
                              _maxplus_vals_fused(prev, g, band=cap)), n

        numpy_s = timeit(_maxplus_vals, prev, g, iters=iters)
        fused_s = timeit(_maxplus_vals_fused, prev, g, iters=iters)
        banded_s = timeit(lambda: _maxplus_vals_fused(prev, g, band=cap),
                          iters=iters)

        from repro.kernels.maxplus import maxplus_conv, maxplus_conv_np
        got = np.asarray(maxplus_conv(prev, g, band=cap, interpret=True))
        oracle = maxplus_conv_np(prev, g, band=cap)
        rel = np.max(np.abs(got - oracle) / np.maximum(np.abs(oracle), 1.0))
        assert rel < PALLAS_TOL, (n, rel)
        pallas_s = timeit(
            lambda: np.asarray(
                maxplus_conv(prev, g, band=cap, interpret=True)),
            iters=iters)

        fused_speedup = numpy_s / fused_s
        banded_speedup = numpy_s / banded_s
        banded_vs_fused = fused_s / banded_s
        if n >= 1024:
            checked_floor = True
            assert banded_speedup >= BANDED_FLOOR, (
                f"banded max-plus speedup {banded_speedup:.1f}x at "
                f"(n={n}, cap={cap}) below the {BANDED_FLOOR:.0f}x floor")
            print(f"[floor check] banded speedup at (n={n}, cap={cap}): "
                  f"{banded_speedup:.1f}x vs dense numpy "
                  f"(floor {BANDED_FLOOR:.0f}x; vs fused "
                  f"{banded_vs_fused:.1f}x)")
        rows.append({
            "workers": n, "cap": cap,
            "numpy_ms": numpy_s * 1e3,
            "fused_ms": fused_s * 1e3,
            "banded_ms": banded_s * 1e3,
            "pallas_interp_ms": pallas_s * 1e3,
            "fused_speedup": fused_speedup,
            "banded_speedup": banded_speedup,
            "banded_vs_fused": banded_vs_fused,
        })
    assert checked_floor, "grid never hit the n >= 1024 banded floor check"
    emit(rows, "maxplus",
         ["workers", "cap", "numpy_ms", "fused_ms", "banded_ms",
          "pallas_interp_ms", "fused_speedup", "banded_speedup",
          "banded_vs_fused"])
    return rows
