"""Max-plus convolution kernel microbenchmark (the planner's DP floor).

Per-convolution latency at n in {256, 1024, 4096} for the four kernels:

  * numpy   — ``_maxplus_vals`` (plain windowed matrix, PR-1 baseline);
  * fused   — ``_maxplus_vals_fused`` dense (tiled add+max, no (n x n)
              candidate matrix);
  * banded  — ``_maxplus_vals_fused`` at band = cap (cap = n/8, the
              ``Task.max_workers`` regime);
  * pallas  — ``kernels.maxplus.maxplus_conv`` in interpret mode (f32;
              the compiled Mosaic path needs a TPU).  On a TPU host the
              compiled kernel is timed too (``pallas_tpu_ms``);
              elsewhere that cell — like every other metric a row skips
              — is emitted as an explicit ``null`` (the key is always
              present), so ``check_regression`` skips it deliberately
              rather than by key absence.

Plus the stacked axis behind the ``engine="batched"`` PlanTable: one
``_maxplus_vals_fused_batched`` call over a (B, n+1) stack vs a Python
loop of B banded 2-D fused calls, at B in {16, 64}.  The stacked win is
a *launch-overhead* win: it is largest where per-row work is small
(n x band below the overhead crossover — exactly the per-level merge
stacks of the batched engine), and decays toward 1x where a single
row's candidate tiles already saturate the memory system (there the
stacked kernel falls through to per-row tiles, so it never loses).

Hard asserts (the harness fails loudly on a regression):

  * fused and banded outputs are bitwise identical to ``_maxplus_vals``
    on their candidate sets; the stacked kernel is bitwise identical to
    its per-slice 2-D calls; pallas (2-D and grid-batched) matches the
    f32 oracle to 1e-6;
  * at n >= 1024 and cap = n/8 the banded kernel is >= 5x faster than
    the dense convolution the engines previously always ran
    (``_maxplus_vals``) — the PR-3 acceptance floor.  ``banded_vs_fused``
    (banded against the *new* dense fused kernel) is also emitted; it
    sits near the 8x candidate-count ratio minus memory-system effects;
  * in the overhead-bound regime (n = 128, the batched engine's
    narrow-level shape) the stacked kernel is >= 2x faster than looped
    2-D fused calls at every batch >= 16 — the PR-5 acceptance floor.
    Larger-n stack rows are emitted unasserted to track the crossover.

``REPRO_BENCH_QUICK=1`` (set by ``run.py --quick``) trims the grids for
CI smoke runs.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.planner import (_maxplus_vals, _maxplus_vals_fused,
                                _maxplus_vals_fused_batched)

GRID_N = [256, 1024, 4096]
CAP_DIV = 8                    # banded regime: cap = n / 8
BANDED_FLOOR = 5.0             # banded >= 5x dense at cap <= n/8, n >= 1024
BATCH_GRID = [(128, 16), (128, 64), (256, 64), (1024, 64)]   # (n, B)
BATCH_FLOOR = 2.0              # stacked >= 2x looped at n = 128, B >= 16
BATCH_FLOOR_N = 128
PALLAS_TOL = 1e-6

COLUMNS = ["workers", "cap", "batch", "numpy_ms", "fused_ms", "banded_ms",
           "pallas_interp_ms", "pallas_tpu_ms", "fused_speedup",
           "banded_speedup", "banded_vs_fused", "stacked_ms", "looped_ms",
           "stack_speedup"]


def _full_row(**cells) -> dict:
    """Row with EVERY column present: metrics a grid point skips are
    explicit nulls in the JSON, never absent keys — ``check_regression``
    then skips them as deliberate "no measurement" markers."""
    row = {c: None for c in COLUMNS}
    row.update(cells)
    return row


def _on_tpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def _data(n: int, cap: int):
    """Monotone DP vector + reward row flat past the cap (the band
    contract the planner guarantees)."""
    rng = np.random.RandomState(n)
    prev = np.maximum.accumulate(rng.uniform(0.0, 100.0, n + 1))
    g = rng.uniform(0.0, 100.0, n + 1)
    g[cap:] = g[cap]
    return prev, g


def run() -> list:
    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    grid = [256, 1024] if quick else GRID_N
    iters = 3 if quick else 7
    rows = []
    checked_floor = False
    for n in grid:
        cap = n // CAP_DIV
        prev, g = _data(n, cap)

        want = _maxplus_vals(prev, g)
        assert np.array_equal(want, _maxplus_vals_fused(prev, g)), n
        assert np.array_equal(want,
                              _maxplus_vals_fused(prev, g, band=cap)), n

        numpy_s = timeit(_maxplus_vals, prev, g, iters=iters)
        fused_s = timeit(_maxplus_vals_fused, prev, g, iters=iters)
        banded_s = timeit(lambda: _maxplus_vals_fused(prev, g, band=cap),
                          iters=iters)

        from repro.kernels.maxplus import maxplus_conv, maxplus_conv_np
        got = np.asarray(maxplus_conv(prev, g, band=cap, interpret=True))
        oracle = maxplus_conv_np(prev, g, band=cap)
        rel = np.max(np.abs(got - oracle) / np.maximum(np.abs(oracle), 1.0))
        assert rel < PALLAS_TOL, (n, rel)
        pallas_s = timeit(
            lambda: np.asarray(
                maxplus_conv(prev, g, band=cap, interpret=True)),
            iters=iters)
        # the compiled Mosaic kernel only exists on a TPU host; off-TPU
        # the cell stays an explicit null
        pallas_tpu_s = None
        if _on_tpu():
            pallas_tpu_s = timeit(
                lambda: np.asarray(
                    maxplus_conv(prev, g, band=cap, interpret=False)),
                iters=iters)

        fused_speedup = numpy_s / fused_s
        banded_speedup = numpy_s / banded_s
        banded_vs_fused = fused_s / banded_s
        if n >= 1024:
            checked_floor = True
            assert banded_speedup >= BANDED_FLOOR, (
                f"banded max-plus speedup {banded_speedup:.1f}x at "
                f"(n={n}, cap={cap}) below the {BANDED_FLOOR:.0f}x floor")
            print(f"[floor check] banded speedup at (n={n}, cap={cap}): "
                  f"{banded_speedup:.1f}x vs dense numpy "
                  f"(floor {BANDED_FLOOR:.0f}x; vs fused "
                  f"{banded_vs_fused:.1f}x)")
        rows.append(_full_row(
            workers=n, cap=cap, batch=None,   # 2-D (unstacked) row
            numpy_ms=numpy_s * 1e3,
            fused_ms=fused_s * 1e3,
            banded_ms=banded_s * 1e3,
            pallas_interp_ms=pallas_s * 1e3,
            pallas_tpu_ms=None if pallas_tpu_s is None else pallas_tpu_s * 1e3,
            fused_speedup=fused_speedup,
            banded_speedup=banded_speedup,
            banded_vs_fused=banded_vs_fused,
        ))
    assert checked_floor, "grid never hit the n >= 1024 banded floor check"

    # ---- stacked axis: one batched call vs a loop of 2-D fused calls ------
    batch_grid = ([g for g in BATCH_GRID if g[0] <= 256] if quick
                  else BATCH_GRID)
    checked_batch_floor = False
    for n, batch in batch_grid:
        cap = n // CAP_DIV
        rng = np.random.RandomState(n + batch)
        prev = np.maximum.accumulate(
            rng.uniform(0.0, 100.0, (batch, n + 1)), axis=1)
        g = rng.uniform(0.0, 100.0, (batch, n + 1))
        g[:, cap:] = g[:, cap:cap + 1]
        bands = [cap] * batch

        got = _maxplus_vals_fused_batched(prev, g, bands)
        for r in range(batch):
            assert np.array_equal(
                got[r], _maxplus_vals_fused(prev[r], g[r], band=cap)), (
                n, batch, r)

        def _looped():
            for r in range(batch):
                _maxplus_vals_fused(prev[r], g[r], band=cap)

        stacked_s = timeit(
            lambda: _maxplus_vals_fused_batched(prev, g, bands),
            iters=iters, number=3)
        looped_s = timeit(_looped, iters=iters, number=3)
        stack_speedup = looped_s / stacked_s
        if n == BATCH_FLOOR_N and batch >= 16:
            checked_batch_floor = True
            assert stack_speedup >= BATCH_FLOOR, (
                f"stacked max-plus speedup {stack_speedup:.2f}x at "
                f"(n={n}, batch={batch}, cap={cap}) below the "
                f"{BATCH_FLOOR:.0f}x floor vs looped 2-D fused calls")
            print(f"[floor check] stacked speedup at (n={n}, "
                  f"batch={batch}, cap={cap}): {stack_speedup:.1f}x vs "
                  f"looped 2-D fused (floor {BATCH_FLOOR:.0f}x)")
        rows.append(_full_row(
            workers=n, cap=cap, batch=batch,
            stacked_ms=stacked_s * 1e3,
            looped_ms=looped_s * 1e3,
            stack_speedup=stack_speedup,
        ))
    assert checked_batch_floor, "grid never hit the stacked floor check"

    # grid-batched Pallas kernel: interpret-mode equivalence at the
    # smallest stack (full timing would measure the interpreter, not the
    # kernel; CI pins broader equivalence in tests/test_kernels.py)
    from repro.kernels.maxplus import maxplus_conv_batched, maxplus_conv_np
    n, batch = 64, 4
    rng = np.random.RandomState(0)
    prev = np.maximum.accumulate(
        rng.uniform(0.0, 100.0, (batch, n + 1)).astype(np.float32), axis=1)
    g = rng.uniform(0.0, 100.0, (batch, n + 1)).astype(np.float32)
    cap = n // CAP_DIV
    g[:, cap:] = g[:, cap:cap + 1]
    got = np.asarray(maxplus_conv_batched(prev, g, [cap] * batch,
                                          interpret=True))
    for r in range(batch):
        oracle = maxplus_conv_np(prev[r], g[r], band=cap)
        rel = np.max(np.abs(got[r] - oracle)
                     / np.maximum(np.abs(oracle), 1.0))
        assert rel < PALLAS_TOL, (r, rel)

    emit(rows, "maxplus", COLUMNS)
    return rows
