"""Roofline table (deliverable g): reads the dry-run sweep results
(results/dryrun.jsonl) and reports, per (arch x shape x mesh):

  compute_s    = HLO_FLOPs / peak            (per-chip module)
  memory_s     = HLO_bytes / HBM_bw
  collective_s = collective_bytes / link_bw
  bottleneck   = argmax of the three
  mfr          = MODEL_FLOPS / (HLO_FLOPs x chips) — useful-compute ratio

Single-pod rows are the canonical roofline table; multi-pod rows prove
the pod axis shards.  Run the sweep first:
    python -m repro.launch.sweep --out results/dryrun.jsonl [--multi-pod]
"""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR, emit

DRYRUN = os.path.join(RESULTS_DIR, "dryrun.jsonl")


def load(path: str = DRYRUN, variant: str = "baseline"):
    rows = []
    if not os.path.exists(path):
        print(f"(no {path}; run repro.launch.sweep first)")
        return rows
    seen = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            if r.get("variant", "baseline") != variant:
                continue
            seen[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(seen.values())


def run(variant: str = "baseline") -> list:
    rows = []
    for r in load(variant=variant):
        if r.get("status") == "skip":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "bottleneck": "SKIP",
                         "note": r["reason"][:44]})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], "bottleneck": r.get("status"),
                         "note": ""})
            continue
        rf = r["roofline"]
        terms = {"compute": rf["compute_s"], "memory": rf["memory_s"],
                 "collective": rf["collective_s"]}
        bottleneck = max(terms, key=terms.get)
        total = sum(terms.values())
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "bottleneck": bottleneck,
            "dominant_frac": terms[bottleneck] / total if total else 0.0,
            "mfr": r.get("model_flops_ratio", 0.0),
            "note": "",
        })
    rows.sort(key=lambda r: (r["mesh"], r["shape"], r["arch"]))
    emit(rows, f"roofline_{variant}",
         ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
          "bottleneck", "dominant_frac", "mfr", "note"])
    return rows
