"""Figure 4 — achieved FLOP/s ratio and aggregate FLOP/s vs worker count
for the GPT-3 family under the analytic plan-search cost model."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core import costmodel
from repro.core.costmodel import A800, TPU_V5E, TaskModel

SIZES = ["gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b"]


def run() -> list:
    rows = []
    for hw in (A800, TPU_V5E):
        for size in SIZES:
            t = TaskModel.from_arch(get_arch(size), seq_len=2048,
                                    global_batch=256)
            for x in range(8, 129, 8):
                plan = costmodel.best_plan(t, x, hw)
                rows.append({
                    "hw": hw.name, "model": size, "workers": x,
                    "agg_tflops": (plan.agg_flops / 1e12) if plan else 0.0,
                    "ratio": costmodel.flops_ratio(t, x, hw),
                    "dp": plan.dp if plan else 0,
                    "tp": plan.tp if plan else 0,
                    "pp": plan.pp if plan else 0,
                })
    emit(rows, "costmodel",
         ["hw", "model", "workers", "agg_tflops", "ratio", "dp", "tp", "pp"])
    # sanity: report the non-monotonic dips (the Fig. 4 phenomenon)
    dips = 0
    for size in SIZES:
        series = [r for r in rows if r["model"] == size and r["hw"] == "A800"]
        for a, b in zip(series, series[1:]):
            if b["ratio"] < a["ratio"] - 1e-9:
                dips += 1
    print(f"non-monotonic ratio dips (A800): {dips}")
    return rows
