"""Figure 4 — achieved FLOP/s ratio and aggregate FLOP/s vs worker count
for the GPT-3 family under the analytic plan-search cost model.

One vectorized ``throughput_curve`` sweep per (hw, model) replaces the
former 16 independent ``best_plan`` searches; the sweep wall-clock is
reported so the planner-engine perf win shows up in the bench trajectory.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core import costmodel
from repro.core.costmodel import A800, TPU_V5E, TaskModel

SIZES = ["gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b"]
MAX_WORKERS = 128


def run() -> list:
    rows = []
    sweep_ms = 0.0
    for hw in (A800, TPU_V5E):
        for size in SIZES:
            t = TaskModel.from_arch(get_arch(size), seq_len=2048,
                                    global_batch=256)
            t0 = time.perf_counter()
            curve = costmodel.throughput_curve(t, MAX_WORKERS, hw)
            sweep_ms += (time.perf_counter() - t0) * 1e3
            for x in range(8, MAX_WORKERS + 1, 8):
                plan = curve.plan(x)
                rows.append({
                    "hw": hw.name, "model": size, "workers": x,
                    "agg_tflops": (plan.agg_flops / 1e12) if plan else 0.0,
                    "ratio": (curve.flops[x] / (x * hw.peak_flops)) if x
                             else 0.0,
                    "dp": plan.dp if plan else 0,
                    "tp": plan.tp if plan else 0,
                    "pp": plan.pp if plan else 0,
                })
    emit(rows, "costmodel",
         ["hw", "model", "workers", "agg_tflops", "ratio", "dp", "tp", "pp"])
    # sanity: report the non-monotonic dips (the Fig. 4 phenomenon)
    dips = 0
    for size in SIZES:
        series = [r for r in rows if r["model"] == size and r["hw"] == "A800"]
        for a, b in zip(series, series[1:]):
            if b["ratio"] < a["ratio"] - 1e-9:
                dips += 1
    print(f"non-monotonic ratio dips (A800): {dips}")
    print(f"full T(t, 1..{MAX_WORKERS}) sweep wall-clock, "
          f"{2 * len(SIZES)} (hw, model) pairs: {sweep_ms:.1f}ms")
    return rows
