"""Figure 9 — transition time after a SEV1 failure, GPT-3 7B, varying
cluster size, Unicron vs the four baselines."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core import transition
from repro.core.detection import ErrorKind, detection_time

STATE_BYTES = 16.0 * get_arch("gpt3-7b").param_count()
AVG_ITER_S = 30.0
CLUSTERS = [16, 32, 64, 128]


def run() -> list:
    rows = []
    for n in CLUSTERS:
        dp = max(n // 16, 1)           # plausible DP degree at this size
        det_uni = detection_time(ErrorKind.LOST_CONNECTION, AVG_ITER_S)
        det_base = detection_time(ErrorKind.LOST_CONNECTION, AVG_ITER_S,
                                  unicron=False)
        uni = transition.estimate_unicron(
            STATE_BYTES, AVG_ITER_S, dp_degree=dp, detect_s=det_uni)
        oob = transition.estimate_baseline(
            STATE_BYTES, det_base, dynamic_reconfig=True, ckpt_restart=False)
        bam = transition.estimate_baseline(
            STATE_BYTES, det_base, dynamic_reconfig=True, ckpt_restart=False)
        meg = transition.estimate_baseline(
            STATE_BYTES, det_base, dynamic_reconfig=False, ckpt_restart=True)
        var = transition.estimate_baseline(
            STATE_BYTES, det_base, dynamic_reconfig=False, ckpt_restart=True)
        rows.append({
            "gpus": n,
            "unicron_s": uni.total,
            "oobleck_s": oob.total,
            "bamboo_s": bam.total,
            "megatron_s": meg.total,
            "varuna_s": var.total,
            "unicron_detect_s": uni.detect_s,
            "unicron_migrate_s": uni.migrate_s,
            "unicron_recompute_s": uni.recompute_s,
        })
    emit(rows, "transition",
         ["gpus", "unicron_s", "oobleck_s", "bamboo_s", "megatron_s",
          "varuna_s", "unicron_detect_s", "unicron_migrate_s",
          "unicron_recompute_s"])
    return rows
