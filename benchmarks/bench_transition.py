"""Figure 9 — transition time after a SEV1 failure, GPT-3 7B, varying
cluster size, Unicron vs the four paper baselines plus the ISSUE-10
recovery-frontier policies (fftrainer hot-spare failover, hierarchical
tiered restore, redundancy-based continuation).

Rows come out of the array-native ``transition.estimate_batch`` matrix —
one (policy x component) call per cluster size — so the bench exercises
the batched simulator's model API; the scalar ``estimate_*`` estimates
are asserted equal cell-for-cell (they remain the reference).  Policies
sharing a recovery class (oobleck/bamboo dynamic reconfiguration,
megatron/varuna checkpoint restart) are computed once and emitted per
policy, instead of re-estimating identical inputs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core import detection, transition
from repro.core.detection import ErrorKind, detection_time, detection_times

STATE_BYTES = 16.0 * get_arch("gpt3-7b").param_count()
AVG_ITER_S = 30.0
CLUSTERS = [16, 32, 64, 128]
POLICIES = ["unicron", "oobleck", "bamboo", "megatron", "varuna",
            "fftrainer", "hierarchical_ckpt", "redundant"]


def run() -> list:
    rows = []
    uni_mask = np.array([p in detection.INBAND_POLICIES for p in POLICIES])
    det = detection_times([ErrorKind.LOST_CONNECTION], AVG_ITER_S,
                          uni_mask)[0]
    assert det[0] == detection_time(ErrorKind.LOST_CONNECTION, AVG_ITER_S)
    assert det[1] == detection_time(ErrorKind.LOST_CONNECTION, AVG_ITER_S,
                                    unicron=False)
    for n in CLUSTERS:
        dp = max(n // 16, 1)           # plausible DP degree at this size
        costs = transition.estimate_batch(
            POLICIES, STATE_BYTES, AVG_ITER_S, dp, det)
        totals = transition.batch_total(costs)
        by = dict(zip(POLICIES, totals))
        # scalar reference: same floats, cell for cell
        uni = transition.estimate_unicron(
            STATE_BYTES, AVG_ITER_S, dp_degree=dp, detect_s=float(det[0]))
        dyn = transition.estimate_baseline(
            STATE_BYTES, float(det[1]), dynamic_reconfig=True,
            ckpt_restart=False)
        ckpt = transition.estimate_baseline(
            STATE_BYTES, float(det[1]), dynamic_reconfig=False,
            ckpt_restart=True)
        fft = transition.estimate_fftrainer(
            STATE_BYTES, AVG_ITER_S, detect_s=float(det[0]))
        hier = transition.estimate_hierarchical(
            STATE_BYTES, AVG_ITER_S, detect_s=float(det[0]))
        red = transition.estimate_redundant()
        assert by["unicron"] == uni.total
        assert by["oobleck"] == by["bamboo"] == dyn.total
        assert by["megatron"] == by["varuna"] == ckpt.total
        assert by["fftrainer"] == fft.total
        assert by["hierarchical_ckpt"] == hier.total
        assert by["redundant"] == red.total == 0.0
        comp = dict(zip(transition.COMPONENTS, costs[0]))
        rows.append({
            "gpus": n,
            "unicron_s": by["unicron"],
            "oobleck_s": by["oobleck"],
            "bamboo_s": by["bamboo"],
            "megatron_s": by["megatron"],
            "varuna_s": by["varuna"],
            "fftrainer_s": by["fftrainer"],
            "hierarchical_s": by["hierarchical_ckpt"],
            "redundant_s": by["redundant"],
            "unicron_detect_s": comp["detect"],
            "unicron_migrate_s": comp["migrate"],
            "unicron_recompute_s": comp["recompute"],
        })
    emit(rows, "transition",
         ["gpus", "unicron_s", "oobleck_s", "bamboo_s", "megatron_s",
          "varuna_s", "fftrainer_s", "hierarchical_s", "redundant_s",
          "unicron_detect_s", "unicron_migrate_s",
          "unicron_recompute_s"])
    return rows
