"""Table 2 — error-detection latency, Unicron vs baseline.

Also micro-benchmarks the in-band monitoring hot path (agent heartbeat +
statistical monitor observe/check) to substantiate the paper's
"no extra overhead on the training process" claim.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, timeit
from repro.core.agent import UnicronAgent
from repro.core.detection import ErrorKind, detection_time
from repro.core.kvstore import KVStore

CASES = [
    ("1 node killed", ErrorKind.LOST_CONNECTION),
    ("2 process killed", ErrorKind.EXITED_ABNORMALLY),
    ("3 exception thrown", ErrorKind.CUDA_ERROR),
    ("4 perf degradation", ErrorKind.TASK_HANG),
]
AVG_ITER_S = 30.0


def run() -> list:
    rows = []
    for label, kind in CASES:
        rows.append({
            "case": label,
            "method": kind.value,
            "unicron_s": detection_time(kind, AVG_ITER_S, unicron=True),
            "baseline_s": detection_time(kind, AVG_ITER_S, unicron=False),
        })

    # Monitoring hot-path overhead (runs on CPU beside the training proc).
    # These paths are tens of nanoseconds to single-digit microseconds, so
    # each timed sample batches >= 10k calls and the row reports ns/op —
    # a handful of single-call samples is pure clock noise and useless for
    # an overhead claim.  The ``overhead`` rows stay excluded from the
    # ``check_regression`` ratio gate (wall-clock, machine-dependent).
    kv = KVStore()
    agent = UnicronAgent(0, kv)

    def hb():
        agent.heartbeat(now=time.time())

    def stat():
        agent.observe_iteration(30.0)
        agent.check_progress(31.0)

    for case, method, fn in (
            ("overhead heartbeat", "kv put+lease", hb),
            ("overhead stat-monitor", "observe+check", stat)):
        per_call_s = timeit(fn, iters=5, number=10_000)
        rows.append({"case": case, "method": method,
                     "unicron_s": per_call_s,
                     "unicron_ns_per_op": per_call_s * 1e9,
                     "baseline_s": 0.0})
    emit(rows, "detection",
         ["case", "method", "unicron_s", "unicron_ns_per_op",
          "baseline_s"])
    return rows
