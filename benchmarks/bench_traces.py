"""Figure 11 — overall training efficiency (accumulated WAF) under the
trace-a / trace-b failure traces, Unicron vs baselines, on the Case#5
multi-task workload (128 GPUs)."""
from __future__ import annotations

from benchmarks.common import case5_tasks, emit
from repro.core.simulator import run_policies
from repro.core.traces import trace_a, trace_b


def run() -> list:
    tasks, assignment = case5_tasks()
    rows = []
    for name, trace in (("trace-a", trace_a()), ("trace-b", trace_b())):
        n_sev1 = sum(1 for e in trace if e.repair_s is not None)
        res = run_policies(tasks, assignment, trace)
        uni = res["unicron"].accumulated_waf
        for policy, r in res.items():
            rows.append({
                "trace": name, "policy": policy,
                "n_failures": len(trace), "n_sev1": n_sev1,
                "accumulated_waf": r.accumulated_waf,
                "unicron_speedup": uni / max(r.accumulated_waf, 1e-9),
                "reconfigs": r.n_reconfigs,
                "downtime_h": r.downtime_s / 3600.0,
            })
    emit(rows, "traces",
         ["trace", "policy", "n_failures", "n_sev1", "accumulated_waf",
          "unicron_speedup", "reconfigs", "downtime_h"])
    # paper claims: 1.2x / 1.9x over Megatron; 3.7-5.8x over the rest
    for r in rows:
        if r["policy"] == "unicron":
            assert r["unicron_speedup"] == 1.0
        else:
            assert r["unicron_speedup"] > 1.0, r
    return rows
