"""Figure 10c / Table 3 — multi-task WAF: Unicron's planner vs the
'equally' / 'weighted' / 'sized' allocation strategies, five cases on a
128-GPU cluster."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import get_arch
from repro.core import planner, waf as waf_mod
from repro.core.costmodel import A800, TaskModel
from repro.core.planner import PlanInput
from repro.core.waf import Task

N_WORKERS = 128
GPN = 8

CASES = {
    1: (["gpt3-7b"] * 6, [1.0] * 6),
    2: (["gpt3-1.3b"] * 3 + ["gpt3-7b"] * 2 + ["gpt3-13b"], [1.0] * 6),
    3: (["gpt3-7b"] * 6, [0.5, 0.8, 1.1, 1.4, 1.7, 2.0]),
    4: (["gpt3-1.3b"] * 3 + ["gpt3-7b"] * 2 + ["gpt3-13b"],
        [0.5, 0.8, 1.1, 1.4, 1.7, 2.0]),
    5: (["gpt3-1.3b"] * 3 + ["gpt3-7b"] * 2 + ["gpt3-13b"],
        [2.0, 1.7, 1.4, 1.1, 0.8, 0.5]),
}


def _tasks(case):
    sizes, weights = CASES[case]
    return [Task(model=TaskModel.from_arch(get_arch(s), global_batch=128),
                 weight=w) for s, w in zip(sizes, weights)]


def _round_to_nodes(xs, total):
    xs = [max(0, int(x) // GPN * GPN) for x in xs]
    while sum(xs) > total:
        xs[xs.index(max(xs))] -= GPN
    i = 0
    while sum(xs) + GPN <= total:
        xs[i % len(xs)] += GPN
        i += 1
    return xs


def _cluster_waf(tasks, assign):
    return sum(waf_mod.waf(t, x, A800) for t, x in zip(tasks, assign))


def run() -> list:
    rows = []
    for case in sorted(CASES):
        tasks = _tasks(case)
        m = len(tasks)
        # unicron: DP planner
        inp = PlanInput(tuple(tasks), (0,) * m, N_WORKERS,
                        d_running=3600.0, d_transition=0.0,
                        faulted=(False,) * m)
        plan = planner.solve(inp, A800)
        strategies = {
            "unicron": list(plan.assignment),
            "equally": _round_to_nodes([N_WORKERS / m] * m, N_WORKERS),
            "weighted": _round_to_nodes(
                [N_WORKERS * t.weight / sum(x.weight for x in tasks)
                 for t in tasks], N_WORKERS),
            "sized": _round_to_nodes(
                [N_WORKERS * t.model.n_params
                 / sum(x.model.n_params for x in tasks) for t in tasks],
                N_WORKERS),
        }
        for name, assign in strategies.items():
            rows.append({
                "case": case, "strategy": name,
                "assignment": "/".join(map(str, assign)),
                "cluster_waf_tflops": _cluster_waf(tasks, assign) / 1e12,
            })
    emit(rows, "waf_multitask",
         ["case", "strategy", "assignment", "cluster_waf_tflops"])
    # invariant: unicron wins (or ties) every case
    for case in sorted(CASES):
        sub = {r["strategy"]: r["cluster_waf_tflops"] for r in rows
               if r["case"] == case}
        best = max(sub.values())
        assert sub["unicron"] >= best - 1e-9, (case, sub)
    print("unicron planner >= all baseline strategies in all 5 cases")
    return rows
