"""Component ablation (beyond the paper): decompose Unicron's trace-b
gain into its three mechanisms by swapping each one for its baseline
counterpart while keeping the other two:

  - detection : in-band (0.3-5.6 s) -> 30-min watchdog
  - transition: partial-result reuse + nearest-principle migration ->
                checkpoint restart (68 min)
  - replanning: whole-cluster WAF planner -> affected-task-only shrink

The paper reports only end-to-end ratios; this table shows WHERE the
win comes from (per Eq. 1's three cost terms).
"""
from __future__ import annotations

from benchmarks.common import case5_tasks, emit
from repro.core.simulator import TraceSimulator
from repro.core.traces import trace_b

ABLATIONS = [
    ("full unicron", {}),
    ("- in-band detection", {"ablate_detection": True}),
    ("- fast transition", {"ablate_transition": True}),
    ("- cluster replanning", {"ablate_replan": True}),
    ("- all three", {"ablate_detection": True, "ablate_transition": True,
                     "ablate_replan": True}),
]


def run() -> list:
    tasks, assignment = case5_tasks()
    trace = trace_b()
    rows = []
    full = None
    for name, kw in ABLATIONS:
        sim = TraceSimulator(tasks, list(assignment), "unicron", **kw)
        res = sim.run(trace)
        if full is None:
            full = res.accumulated_waf
        rows.append({
            "config": name,
            "accumulated_waf": res.accumulated_waf,
            "fraction_of_full": res.accumulated_waf / full,
            "downtime_h": res.downtime_s / 3600.0,
        })
    emit(rows, "ablation",
         ["config", "accumulated_waf", "fraction_of_full", "downtime_h"])
    # sanity: every ablation costs something; all-three costs the most
    assert all(r["fraction_of_full"] <= 1.0 + 1e-9 for r in rows)
    assert rows[-1]["fraction_of_full"] == min(r["fraction_of_full"]
                                               for r in rows)
    return rows
