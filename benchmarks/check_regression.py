"""Benchmark regression gate.

Compares a fresh ``run.py --quick`` output directory against the committed
baselines under ``results/`` and fails (exit 1) on regressions.

Gated metrics are machine-independent by construction — speedup ratios and
deterministic model outputs — so the gate is robust to CI runners being
slower or faster than the machine that recorded the baselines:

* ``higher``: ratios (e.g. vectorized-vs-scalar speedups) must not drop
  below ``baseline / slack``;
* ``equal``: deterministic analytic-model outputs must match the baseline
  to a tight relative tolerance (accidental cost-model drift is a
  regression even when it is fast);
* ``lower``: same-machine overhead ratios (e.g. journaling-on vs
  journaling-off dispatch latency) must not rise above
  ``baseline * slack``.

Rows are matched by their key columns; fresh rows without a baseline
counterpart (new configurations) and baseline rows the quick grid does not
reproduce are skipped.

Usage::

    python benchmarks/check_regression.py \
        --baseline results --fresh fresh-results --slack 2.0
"""

from __future__ import annotations

import argparse
import json
import os
import sys

EQ_TOL = 1e-6

SPECS = {
    "planner_scale": {
        "keys": ("workers", "tasks"),
        "higher": (
            "solve_speedup",
            "rebuild_speedup",
            "churn_speedup",
            "table_speedup",
            "fused_speedup",
        ),
        # one compiled device dispatch per whole-table rebuild step —
        # any drift means the fused engine stopped being one-program
        "equal": ("fused_dispatches",),
        # sub-ms small-n measurements are too noisy for a ratio gate
        "min_workers": 256,
    },
    "maxplus": {
        # "batch" is null on the 2-D kernel rows, (n, B) on the stacked
        # axis rows — part of the key either way
        "keys": ("workers", "cap", "batch"),
        "higher": ("fused_speedup", "banded_speedup", "stack_speedup"),
        # sub-ms small-n measurements are too noisy for a ratio gate;
        # the stacked axis is exempt (its floor is asserted in-bench and
        # its ratios are launch-overhead ratios, stable at small n)
        "min_workers": 1024,
        "min_workers_exempt": ("stack_speedup",),
    },
    "cluster_sim": {
        # engine axis: "vector" rows carry the vector-vs-scalar suite
        # speedup, "batched" rows the batched-vs-vector (shared planner
        # state) speedup, the batched per-policy waf_mean and the cold
        # planner-engine ratio (batched vs segtree PlanTable engine)
        "keys": ("config", "policy", "engine"),
        "higher": ("suite_speedup", "batched_speedup", "cold_plan_speedup"),
        "equal": ("waf_mean", "events"),
    },
    "serving_slo": {
        # policy rows carry the per-engine WAF totals on the mixed
        # training+serving rate-event trace; the "planner" row the
        # failure-replan trade-off (all deterministic: seeded trace,
        # analytic objectives).  Walls and rel-err columns are not gated
        # (rel errs are asserted < 1e-6 in-bench).
        "keys": ("config", "policy"),
        "equal": ("events", "scalar_waf", "plan_diff_slots",
                  "goodput_mixed_rps", "goodput_wafonly_rps",
                  "train_waf_mixed", "train_waf_wafonly"),
    },
    "costmodel": {
        "keys": ("hw", "model", "workers"),
        "equal": ("agg_tflops", "dp", "tp", "pp"),
    },
    "detection": {
        "keys": ("case", "method"),
        "equal": ("unicron_s", "baseline_s"),
        "skip_key_prefix": "overhead",  # measured latencies, not model output
    },
    "transition": {
        "keys": ("gpus",),
        "equal": ("unicron_s", "megatron_s", "oobleck_s", "bamboo_s",
                  "fftrainer_s", "hierarchical_s", "redundant_s"),
    },
    "frontier": {
        # per-(config, policy) points on the (downtime, WAF) plane plus
        # the frontier/dominance booleans — all deterministic (seeded
        # calibrated traces, batched engine, analytic cost model); a
        # drift in any of them means the recovery model moved
        "keys": ("config", "policy"),
        "equal": ("waf_mean", "downtime_s", "events", "on_frontier",
                  "beyond_paper"),
    },
    "chaos": {
        # per-class reconvergence rows are fully deterministic (seeded
        # schedules, tick-driven harness); the journal_overhead row gates
        # the journaling-on/off latency ratios, which must stay near 1
        # because journal writes live outside the timed dispatch windows
        "keys": ("case",),
        "equal": ("converged", "waf_delta", "reconverge_s", "n_crashes"),
        "lower": ("churn_overhead_ratio", "dispatch_overhead_ratio"),
    },
    "controlplane": {
        # sharded rows carry the ingestion speedup vs the legacy
        # scan-based loop (also floor-asserted >= 20x in-bench); the
        # event counts are deterministic — a drift means the drain
        # consumed a different stream, a semantic regression
        "keys": ("config", "store", "agents"),
        "higher": ("ingest_speedup",),
        "equal": ("events", "loop_events", "sev1_replans"),
    },
}


def _load(path):
    with open(path) as f:
        return json.load(f)


def _num(value):
    """Numeric cell value, or None for a skipped metric.

    Benches emit null for metrics they skipped at a grid point (e.g. the
    scalar reference beyond its tractable sizes) and new columns are
    simply absent from old baselines — both are explicit "no
    measurement" markers, never comparison failures.  Legacy baselines
    recorded skips as empty strings; treat those the same way."""
    if value is None or (isinstance(value, str) and not value.strip()):
        return None
    try:
        return float(value)
    except (TypeError, ValueError):
        return None


def check_bench(name, spec, baseline_rows, fresh_rows, slack):
    """Returns a list of violation strings for one benchmark."""
    keys = spec["keys"]

    def key_of(row):
        return tuple(str(row.get(k)) for k in keys)

    baseline = {key_of(r): r for r in baseline_rows}
    violations = []
    compared = 0
    min_workers = spec.get("min_workers")
    exempt = spec.get("min_workers_exempt", ())
    for row in fresh_rows:
        key = key_of(row)
        prefix = spec.get("skip_key_prefix")
        if prefix and any(part.startswith(prefix) for part in key):
            continue
        skip_small = False
        if min_workers is not None:
            workers = _num(row.get("workers"))
            skip_small = workers is None or workers < min_workers
        base = baseline.get(key)
        if base is None:
            continue
        for metric in spec.get("higher", ()):
            if skip_small and metric not in exempt:
                continue
            fresh_v, base_v = _num(row.get(metric)), _num(base.get(metric))
            if fresh_v is None or base_v is None or base_v <= 0:
                continue
            compared += 1
            if fresh_v < base_v / slack:
                violations.append(
                    f"{name}{key}: {metric} {fresh_v:.3g} < "
                    f"baseline {base_v:.3g} / slack {slack:g}"
                )
        for metric in spec.get("lower", ()):
            if skip_small and metric not in exempt:
                continue
            fresh_v, base_v = _num(row.get(metric)), _num(base.get(metric))
            if fresh_v is None or base_v is None or base_v <= 0:
                continue
            compared += 1
            if fresh_v > base_v * slack:
                violations.append(
                    f"{name}{key}: {metric} {fresh_v:.3g} > "
                    f"baseline {base_v:.3g} * slack {slack:g}"
                )
        for metric in spec.get("equal", ()):
            if skip_small and metric not in exempt:
                continue
            fresh_v, base_v = _num(row.get(metric)), _num(base.get(metric))
            if fresh_v is None or base_v is None:
                continue
            compared += 1
            denom = max(abs(base_v), 1.0)
            if abs(fresh_v - base_v) / denom > EQ_TOL:
                violations.append(
                    f"{name}{key}: {metric} {fresh_v!r} != "
                    f"baseline {base_v!r} (tol {EQ_TOL:g})"
                )
    print(f"[{name}] {compared} metric comparisons, {len(violations)} violations")
    return violations


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--baseline", default="results")
    parser.add_argument("--fresh", default="fresh-results")
    parser.add_argument(
        "--slack",
        type=float,
        default=float(os.environ.get("REPRO_BENCH_SLACK", "2.0")),
        help="allowed ratio degradation factor (default 2.0)",
    )
    args = parser.parse_args(argv)

    violations = []
    checked = 0
    for name, spec in SPECS.items():
        baseline_path = os.path.join(args.baseline, f"bench_{name}.json")
        fresh_path = os.path.join(args.fresh, f"bench_{name}.json")
        if not os.path.exists(fresh_path):
            continue  # bench not part of this (quick) run
        if not os.path.exists(baseline_path):
            print(f"[{name}] no committed baseline — skipping")
            continue
        checked += 1
        violations += check_bench(
            name, spec, _load(baseline_path), _load(fresh_path), args.slack
        )
    if checked == 0:
        print("no benchmarks compared — wrong --fresh directory?")
        return 1
    if violations:
        print(f"\n{len(violations)} regression(s):")
        for v in violations:
            print(f"  {v}")
        return 1
    print("regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
