"""Planner-engine scale benchmark (§5.2 at production scale).

Measures, over (workers, tasks) in {64..1024} x {4..32}:

  * ``solve``            — vectorized max-plus DP latency, vs the retained
                           scalar ``solve_reference`` where tractable;
  * ``PlanTable`` rebuild — incremental build (batched engine default) vs
                           the scalar scenario-by-scenario reference where
                           tractable;
  * dispatch             — ``table.lookup`` latency (the O(1) failure-time
                           path);
  * churn rebuild        — a seeded churn walk (one assignment change +
                           two scenario lookups per step) through a shared
                           ``PlannerCache``, segment-tree engine vs the
                           PR-2 chain engine, on a cap-aware fleet at
                           (n=1024, m=64);
  * whole-table rebuild  — a seeded churn walk where every step rebuilds
                           the FULL scenario table (totals for every
                           ``fault:i``/``finish:i``/``join`` key) and
                           dispatches one plan: the level-synchronous
                           batched engine (stacked level launches,
                           value-only assembly, one lazy traceback) vs
                           the per-merge segtree engine (a ``lookup`` —
                           convolutions + argmax traceback + plan WAF —
                           per scenario), fair-share caps at
                           (n=1024, m=64);
  * replan latency       — the same whole-table walk on the fused engine
                           (the entire rebuild compiled into ONE jitted
                           ``lax.scan`` dispatch) vs the batched engine,
                           with a dispatch-count column asserting exactly
                           one device dispatch per rebuild step.

Skipped reference cells (the scalar path is O(m n^2) Python — it only
runs where that finishes in seconds) are emitted as null, never as
``""``; ``check_regression`` skips null/absent metrics explicitly.

Hard asserts, so the harness fails loudly on a regression:

  * wherever the scalar reference runs, total rewards match to 1e-6 on
    every solve and every table scenario;
  * at (n=256, m=16) the incremental rebuild is >= 50x faster than the
    scalar reference;
  * the segment-tree churn walk is >= 3x faster than the chain engine at
    (n=1024, m=64), with identical-to-1e-6 rewards between the engines
    there and against ``solve_reference`` on the small verification walk;
  * the batched whole-table walk is >= 3x faster than the segtree engine
    at (n=1024, m=64), with every per-step scenario total equal to 1e-6
    across engines there and against ``solve_reference`` on the small
    verification walk;
  * the fused whole-table walk is >= 1.5x faster than the batched engine
    at (n=1024, m=64) (one wall-clock retry of both lanes is allowed —
    the ratio is always same-machine, same-run), issues exactly
    ``CHURN_STEPS`` device dispatches (one per rebuild), and its totals
    match the batched stream to 1e-6 there and ``solve_reference`` on
    the small verification walk.

``REPRO_BENCH_QUICK=1`` (set by ``run.py --quick``) trims the grid for CI
smoke runs.
"""
from __future__ import annotations

import os
import random
import time

from benchmarks.common import emit, fleet_tasks, timeit
from repro.core.costmodel import A800
from repro.core.planner import (PlanInput, PlannerCache, PlanTable, solve,
                                solve_reference)

GRID_N = [64, 128, 256, 512, 1024]
GRID_M = [4, 8, 16, 32]
# the scalar path is O(m n^2) Python per scenario: only time it where that
# finishes in seconds, and extrapolate nothing beyond what was measured
REF_LIMIT = (256, 16)
SPEEDUP_FLOOR = 50.0      # hard floor at (n, m) == REF_LIMIT
CHURN_N, CHURN_M = 1024, 64
CHURN_STEPS = 12
CHURN_FLOOR = 3.0         # segtree churn walk vs chain engine
TABLE_FLOOR = 3.0         # batched whole-table walk vs segtree engine
FUSED_FLOOR = 1.5         # fused whole-table walk vs batched engine
REL_TOL = 1e-6

_tasks = fleet_tasks


def _rel_err(a: float, b: float) -> float:
    return abs(a - b) / max(1.0, abs(b))


def _churn_walk(tasks, assignment0, n, engine, steps, seed=0,
                changes_per_step=3):
    """Seeded churn workload: per step, look up one fault and one finish
    scenario from the cached lazy table of the current state, then apply
    one reconfiguration-sized change (a plan rarely moves a single task —
    ``changes_per_step`` assignments shift at once).  Identical seeds give
    identical key/assignment sequences across engines, so the reward
    streams must agree."""
    cache = PlannerCache()
    assignment = list(assignment0)
    rng = random.Random(seed)
    m = len(tasks)
    rewards = []
    t0 = time.perf_counter()
    for _ in range(steps):
        table = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                            n_budget=n + 8, engine=engine)
        for key in (f"fault:{rng.randrange(m)}",
                    f"finish:{rng.randrange(m)}"):
            rewards.append((key, tuple(assignment),
                            table.lookup(key).total_reward))
        for _ in range(changes_per_step):
            assignment[rng.randrange(m)] = rng.choice((8, 12, 16, 20, 24))
    return time.perf_counter() - t0, rewards


def _reference_reward(tasks, key, assignment, m):
    """``solve_reference`` total for one scenario of one walk state."""
    kind, _, idx = key.partition(":")
    n_now = sum(assignment)
    if kind == "join":
        inp = PlanInput(tuple(tasks), tuple(assignment), n_now + 8,
                        3600.0, 120.0, (False,) * m)
    elif kind == "fault":
        ti = int(idx)
        inp = PlanInput(tuple(tasks), tuple(assignment),
                        max(n_now - 8, 0), 3600.0, 120.0,
                        tuple(i == ti for i in range(m)))
    else:
        ti = int(idx)
        rem_t = tuple(tasks[:ti] + tasks[ti + 1:])
        rem_a = tuple(assignment[:ti] + assignment[ti + 1:])
        inp = PlanInput(rem_t, rem_a, n_now, 3600.0, 120.0,
                        (False,) * (m - 1))
    return solve_reference(inp, A800).total_reward


def _churn_reference_check(n: int, m: int, steps: int) -> None:
    """Small walk where the scalar reference is tractable: every looked-up
    segment-tree scenario must match ``solve_reference`` to 1e-6."""
    tasks = _tasks(m, max_workers=max(n // 8, 8))
    _, rewards = _churn_walk(tasks, [n // m] * m, n, "segtree", steps)
    for key, assignment, got in rewards:
        want = _reference_reward(tasks, key, list(assignment), m)
        assert _rel_err(got, want) < REL_TOL, (key, assignment, got, want)


def _table_walk(tasks, assignment0, n, engine, steps, seed=0,
                changes_per_step=3, values=(4, 8, 12, 16)):
    """Whole-table churn workload: per step, rebuild the FULL scenario
    table of the current state — every ``fault:i``/``finish:i``/``join``
    total materialized via ``rebuild_values`` (the batched engine's
    value-only level sweeps; the other engines assemble each plan) — then
    dispatch ONE fault plan, then apply one reconfiguration-sized change.
    Identical seeds give identical key/assignment sequences across
    engines, so the total streams must agree.

    Churn draws stay within the fleet's worker caps so ``sum(assignment)``
    never exceeds the fixed ``n_budget`` (otherwise the DP width — and
    with it every content-keyed cache entry — would silently change
    between steps).  Reward rows for every (task, draw) pair are
    pre-warmed per engine lane through the same cache the walk uses:
    both lanes then measure pure engine work, not cost-model sweeps (and
    the fused lane's prewarm compiles its program, so the timed walk
    re-dispatches the cached executable — zero traces).

    Returns ``(elapsed_s, rewards, device_dispatches)``: the dispatch
    count sums the per-step ``batch_stats["device_dispatches"]`` deltas
    around each rebuild — ``steps`` on the fused engine (one compiled
    program execution per whole-table rebuild), 0 elsewhere."""
    cache = PlannerCache()
    assignment = list(assignment0)
    rng = random.Random(seed)
    m = len(tasks)
    for v in sorted(set(values) | {assignment0[0]}):   # warm reward rows
        warm = PlanTable(tasks, [v] * m, A800, 3600.0, 120.0,
                         lazy=True, cache=cache, n_budget=n + 8,
                         engine=engine)
        warm.rebuild_values()
    rewards = []
    dispatches = 0
    t0 = time.perf_counter()
    for _ in range(steps):
        table = cache.table(tasks, assignment, A800, 3600.0, 120.0,
                            n_budget=n + 8, engine=engine)
        before = table.batch_stats.get("device_dispatches", 0)
        totals = table.rebuild_values()
        dispatches += table.batch_stats.get("device_dispatches", 0) - before
        state = tuple(assignment)
        rewards.extend((key, state, total)
                       for key, total in sorted(totals.items()))
        plan = table.lookup(f"fault:{rng.randrange(m)}")
        rewards.append(("dispatch", state, plan.total_reward))
        for _ in range(changes_per_step):
            assignment[rng.randrange(m)] = rng.choice(values)
    return time.perf_counter() - t0, rewards, dispatches


def _table_reference_check(n: int, m: int, steps: int,
                           engine: str = "batched") -> None:
    """Small whole-table walk where the scalar reference is tractable:
    every scenario total of ``engine`` must match ``solve_reference``.
    Churn draws stay within this config's caps (the walk's cap/budget
    invariant), like the measured walk's do."""
    tasks = _tasks(m, max_workers=max(n // m, 8))
    _, rewards, _ = _table_walk(tasks, [n // m] * m, n, engine, steps,
                                values=(4, 8, 12))
    for key, assignment, got in rewards:
        if key == "dispatch":
            continue
        want = _reference_reward(tasks, key, list(assignment), m)
        assert _rel_err(got, want) < REL_TOL, (key, assignment, got, want)


def run() -> list:
    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    grid_n = [64, 256] if quick else GRID_N
    grid_m = [4, 16] if quick else GRID_M
    iters = 1 if quick else 3
    rows = []
    checked_floor = False
    for m in grid_m:
        tasks = _tasks(m)
        for n in grid_n:
            if n < 2 * m:
                continue
            assignment = [n // m] * m
            inp = PlanInput(tuple(tasks), tuple(assignment), n,
                            3600.0, 120.0, (False,) * m)
            with_ref = n <= REF_LIMIT[0] and m <= REF_LIMIT[1]

            solve_fast_s = timeit(solve, inp, A800, iters=iters)
            rebuild_fast_s = timeit(
                lambda: PlanTable(tasks, assignment, A800, 3600.0, 120.0),
                iters=iters)
            table = PlanTable(tasks, assignment, A800, 3600.0, 120.0)
            dispatch_s = timeit(table.lookup, "fault:0", warmup=2, iters=5)

            row = {"workers": n, "tasks": m,
                   "solve_ms": solve_fast_s * 1e3,
                   "rebuild_ms": rebuild_fast_s * 1e3,
                   "dispatch_us": dispatch_s * 1e6,
                   # null (not ""): the scalar reference is skipped here
                   "solve_ref_ms": None, "solve_speedup": None,
                   "rebuild_ref_ms": None, "rebuild_speedup": None,
                   "reward_match": None}
            if with_ref:
                fast = solve(inp, A800)
                t0 = time.perf_counter()
                ref = solve_reference(inp, A800)
                solve_ref_s = time.perf_counter() - t0
                assert _rel_err(fast.total_reward,
                                ref.total_reward) < REL_TOL, (n, m)
                t0 = time.perf_counter()
                ref_table = PlanTable(tasks, assignment, A800, 3600.0,
                                      120.0, incremental=False,
                                      solver=solve_reference)
                rebuild_ref_s = time.perf_counter() - t0
                mismatches = [k for k in ref_table.table if _rel_err(
                    table.table[k].total_reward,
                    ref_table.table[k].total_reward) >= REL_TOL]
                assert not mismatches, (n, m, mismatches)
                row.update(
                    solve_ref_ms=solve_ref_s * 1e3,
                    solve_speedup=solve_ref_s / solve_fast_s,
                    rebuild_ref_ms=rebuild_ref_s * 1e3,
                    rebuild_speedup=rebuild_ref_s / rebuild_fast_s,
                    reward_match=len(ref_table.table))
                if (n, m) == REF_LIMIT:
                    checked_floor = True
                    speedup = rebuild_ref_s / rebuild_fast_s
                    assert speedup >= SPEEDUP_FLOOR, (
                        f"PlanTable rebuild speedup {speedup:.0f}x at "
                        f"(n={n}, m={m}) below the {SPEEDUP_FLOOR:.0f}x floor")
                    print(f"[floor check] rebuild speedup at (n={n}, m={m}): "
                          f"{speedup:.0f}x (floor {SPEEDUP_FLOOR:.0f}x)")
            rows.append(row)
    if not quick:
        assert checked_floor, "grid never hit the (256, 16) floor check"

    # ---- churn-rebuild walk: segment tree vs the PR-2 chain engine --------
    # Cap-aware fleet: every task capped at twice its fair share (DP-width
    # limits at fleet scale), which is what lets the tree's leaf-ward
    # convolutions run banded while the chain baseline stays dense.
    _churn_reference_check(n=96, m=8, steps=2 if quick else 4)
    n, m = CHURN_N, CHURN_M
    tasks = _tasks(m, max_workers=2 * (n // m))
    assignment0 = [n // m] * m
    # warm the memoized cost-model sweeps so neither engine pays them
    _churn_walk(tasks, assignment0, n, "segtree", 1, seed=99)
    seg_s, seg_rewards = _churn_walk(tasks, assignment0, n, "segtree",
                                     CHURN_STEPS)
    chain_s, chain_rewards = _churn_walk(tasks, assignment0, n, "chain",
                                         CHURN_STEPS)
    for (key, asg, a), (_, _, b) in zip(seg_rewards, chain_rewards):
        assert _rel_err(a, b) < REL_TOL, (key, asg, a, b)
    churn_speedup = chain_s / seg_s
    assert churn_speedup >= CHURN_FLOOR, (
        f"segment-tree churn walk {churn_speedup:.1f}x at (n={n}, m={m}) "
        f"below the {CHURN_FLOOR:.0f}x floor vs the chain engine")
    print(f"[floor check] churn-rebuild speedup at (n={n}, m={m}, "
          f"{CHURN_STEPS} steps): {churn_speedup:.1f}x "
          f"(floor {CHURN_FLOOR:.0f}x)")

    # ---- whole-table rebuild walk: batched engine vs segtree --------------
    # Fair-share caps (n/m — the tightest cap every fleet model stays
    # feasible under) and cap-bounded churn draws, so DP chain keys stay
    # stable and the banded kernels operate in their design regime.
    _table_reference_check(n=96, m=8, steps=2 if quick else 4)
    _table_reference_check(n=96, m=8, steps=2 if quick else 4,
                           engine="fused")
    tasks = _tasks(m, max_workers=n // m)
    bat_s, bat_rewards, _ = _table_walk(tasks, assignment0, n, "batched",
                                        CHURN_STEPS)
    tseg_s, tseg_rewards, _ = _table_walk(tasks, assignment0, n, "segtree",
                                          CHURN_STEPS)
    for (key, asg, a), (_, _, b) in zip(bat_rewards, tseg_rewards):
        assert _rel_err(a, b) < REL_TOL, (key, asg, a, b)
    table_speedup = tseg_s / bat_s
    assert table_speedup >= TABLE_FLOOR, (
        f"batched whole-table walk {table_speedup:.1f}x at (n={n}, m={m}) "
        f"below the {TABLE_FLOOR:.0f}x floor vs the segtree engine")
    print(f"[floor check] whole-table rebuild speedup at (n={n}, m={m}, "
          f"{CHURN_STEPS} steps, {len(bat_rewards)} scenario totals): "
          f"{table_speedup:.1f}x (floor {TABLE_FLOOR:.0f}x)")

    # ---- replan latency: fused one-program engine vs batched --------------
    # Same walk, same seed: the fused lane compiles its whole-table
    # rebuild into ONE jitted lax.scan dispatch per step (program cached
    # across the walk — the prewarm traced it, the steps only execute).
    fus_s, fus_rewards, fus_disp = _table_walk(tasks, assignment0, n,
                                               "fused", CHURN_STEPS)
    for (key, asg, a), (_, _, b) in zip(fus_rewards, bat_rewards):
        assert _rel_err(a, b) < REL_TOL, (key, asg, a, b)
    assert fus_disp == CHURN_STEPS, (
        f"fused walk issued {fus_disp} device dispatches over "
        f"{CHURN_STEPS} whole-table rebuilds (expected exactly 1 each)")
    replan_bat_s = bat_s
    fused_speedup = replan_bat_s / fus_s
    if fused_speedup < FUSED_FLOOR:
        # one retry against wall-clock noise (±40% observed on shared
        # runners): re-measure BOTH lanes so the ratio stays same-run
        bat2_s, _, _ = _table_walk(tasks, assignment0, n, "batched",
                                   CHURN_STEPS)
        fus2_s, _, disp2 = _table_walk(tasks, assignment0, n, "fused",
                                       CHURN_STEPS)
        assert disp2 == CHURN_STEPS, disp2
        if bat2_s / fus2_s > fused_speedup:
            replan_bat_s, fus_s = bat2_s, fus2_s
            fused_speedup = bat2_s / fus2_s
    assert fused_speedup >= FUSED_FLOOR, (
        f"fused whole-table walk {fused_speedup:.2f}x at (n={n}, m={m}) "
        f"below the {FUSED_FLOOR:.1f}x floor vs the batched engine")
    print(f"[floor check] fused replan speedup at (n={n}, m={m}, "
          f"{CHURN_STEPS} steps, {fus_disp} device dispatches): "
          f"{fused_speedup:.2f}x (floor {FUSED_FLOOR:.1f}x)")
    rows.append({"workers": n, "tasks": m,
                 "solve_ms": None, "solve_ref_ms": None,
                 "solve_speedup": None, "rebuild_ms": None,
                 "rebuild_ref_ms": None, "rebuild_speedup": None,
                 "dispatch_us": None,
                 "reward_match": len(seg_rewards) + len(bat_rewards),
                 "churn_segtree_ms": seg_s * 1e3,
                 "churn_chain_ms": chain_s * 1e3,
                 "churn_speedup": churn_speedup,
                 "table_batched_ms": bat_s * 1e3,
                 "table_segtree_ms": tseg_s * 1e3,
                 "table_speedup": table_speedup,
                 "replan_fused_ms": fus_s * 1e3,
                 "replan_batched_ms": replan_bat_s * 1e3,
                 "fused_speedup": fused_speedup,
                 "fused_dispatches": fus_disp})

    emit(rows, "planner_scale",
         ["workers", "tasks", "solve_ms", "solve_ref_ms", "solve_speedup",
          "rebuild_ms", "rebuild_ref_ms", "rebuild_speedup", "dispatch_us",
          "reward_match", "churn_segtree_ms", "churn_chain_ms",
          "churn_speedup", "table_batched_ms", "table_segtree_ms",
          "table_speedup", "replan_fused_ms", "replan_batched_ms",
          "fused_speedup", "fused_dispatches"])
    return rows
