"""Planner-engine scale benchmark (§5.2 at production scale).

Measures, over (workers, tasks) in {64..1024} x {4..32}:

  * ``solve``            — vectorized max-plus DP latency, vs the retained
                           scalar ``solve_reference`` where tractable;
  * ``PlanTable`` rebuild — incremental build (shared reward rows +
                           prefix/suffix DPs) vs the scalar
                           scenario-by-scenario reference where tractable;
  * dispatch             — ``table.lookup`` latency (the O(1) failure-time
                           path).

Wherever the reference runs, total rewards must match to 1e-6 on every
solve and every table scenario; at (n=256, m=16) the incremental rebuild
must be >= 50x faster than the scalar reference — both are hard-asserted,
so the harness fails loudly on a regression.

``REPRO_BENCH_QUICK=1`` (set by ``run.py --quick``) trims the grid for CI
smoke runs.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import emit, fleet_tasks, timeit
from repro.core.costmodel import A800
from repro.core.planner import PlanInput, PlanTable, solve, solve_reference

GRID_N = [64, 128, 256, 512, 1024]
GRID_M = [4, 8, 16, 32]
# the scalar path is O(m n^2) Python per scenario: only time it where that
# finishes in seconds, and extrapolate nothing beyond what was measured
REF_LIMIT = (256, 16)
SPEEDUP_FLOOR = 50.0      # hard floor at (n, m) == REF_LIMIT
REL_TOL = 1e-6

_tasks = fleet_tasks


def _rel_err(a: float, b: float) -> float:
    return abs(a - b) / max(1.0, abs(b))


def run() -> list:
    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    grid_n = [64, 256] if quick else GRID_N
    grid_m = [4, 16] if quick else GRID_M
    iters = 1 if quick else 3
    rows = []
    checked_floor = False
    for m in grid_m:
        tasks = _tasks(m)
        for n in grid_n:
            if n < 2 * m:
                continue
            assignment = [n // m] * m
            inp = PlanInput(tuple(tasks), tuple(assignment), n,
                            3600.0, 120.0, (False,) * m)
            with_ref = n <= REF_LIMIT[0] and m <= REF_LIMIT[1]

            solve_fast_s = timeit(solve, inp, A800, iters=iters)
            rebuild_fast_s = timeit(
                lambda: PlanTable(tasks, assignment, A800, 3600.0, 120.0),
                iters=iters)
            table = PlanTable(tasks, assignment, A800, 3600.0, 120.0)
            dispatch_s = timeit(table.lookup, "fault:0", warmup=2, iters=5)

            row = {"workers": n, "tasks": m,
                   "solve_ms": solve_fast_s * 1e3,
                   "rebuild_ms": rebuild_fast_s * 1e3,
                   "dispatch_us": dispatch_s * 1e6,
                   "solve_ref_ms": "", "solve_speedup": "",
                   "rebuild_ref_ms": "", "rebuild_speedup": "",
                   "reward_match": ""}
            if with_ref:
                fast = solve(inp, A800)
                t0 = time.perf_counter()
                ref = solve_reference(inp, A800)
                solve_ref_s = time.perf_counter() - t0
                assert _rel_err(fast.total_reward,
                                ref.total_reward) < REL_TOL, (n, m)
                t0 = time.perf_counter()
                ref_table = PlanTable(tasks, assignment, A800, 3600.0,
                                      120.0, incremental=False,
                                      solver=solve_reference)
                rebuild_ref_s = time.perf_counter() - t0
                mismatches = [k for k in ref_table.table if _rel_err(
                    table.table[k].total_reward,
                    ref_table.table[k].total_reward) >= REL_TOL]
                assert not mismatches, (n, m, mismatches)
                row.update(
                    solve_ref_ms=solve_ref_s * 1e3,
                    solve_speedup=solve_ref_s / solve_fast_s,
                    rebuild_ref_ms=rebuild_ref_s * 1e3,
                    rebuild_speedup=rebuild_ref_s / rebuild_fast_s,
                    reward_match=len(ref_table.table))
                if (n, m) == REF_LIMIT:
                    checked_floor = True
                    speedup = rebuild_ref_s / rebuild_fast_s
                    assert speedup >= SPEEDUP_FLOOR, (
                        f"PlanTable rebuild speedup {speedup:.0f}x at "
                        f"(n={n}, m={m}) below the {SPEEDUP_FLOOR:.0f}x floor")
                    print(f"[floor check] rebuild speedup at (n={n}, m={m}): "
                          f"{speedup:.0f}x (floor {SPEEDUP_FLOOR:.0f}x)")
            rows.append(row)
    if not quick:
        assert checked_floor, "grid never hit the (256, 16) floor check"
    emit(rows, "planner_scale",
         ["workers", "tasks", "solve_ms", "solve_ref_ms", "solve_speedup",
          "rebuild_ms", "rebuild_ref_ms", "rebuild_speedup", "dispatch_us",
          "reward_match"])
    return rows
