"""Figure 10a/b — throughput parity: Unicron introduces no overhead over
the plain trainer.

Measured for real on CPU with reduced models: the SAME jitted train step
runs (a) bare and (b) under full Unicron management (agent heartbeat +
statistical monitor + in-memory checkpointing on the interval).  Reported
as samples/s; parity ratio should be ~1.  Fig. 10b's achieved-FLOP/s
ratios come from the cost model at the paper's scales.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.core.agent import UnicronAgent
from repro.core.costmodel import A800, TaskModel, flops_ratio
from repro.core.kvstore import KVStore
from repro.data.pipeline import SyntheticLM, stack_microbatches
from repro.models.model import build_model
from repro.optim import AdamW, constant
from repro.train.state import init_train_state
from repro.train.step import make_train_step

ARCHS = ["gemma-2b", "qwen3-4b", "mamba2-780m"]
STEPS, SEQ, BATCH, N_MICRO = 8, 128, 8, 2


def _run_loop(managed: bool, arch: str, tmp: str) -> float:
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    opt = AdamW(lr=constant(1e-3))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    data = SyntheticLM(cfg, seq_len=SEQ, global_batch=BATCH)
    step = jax.jit(make_train_step(model, opt, N_MICRO))
    agent = UnicronAgent(0, KVStore()) if managed else None
    mgr = CheckpointManager(tmp, n_ranks=1, persist_every=4,
                            task=f"bench-{arch}") if managed else None
    # warmup/compile
    state, _ = step(state, stack_microbatches(data.batch(0), N_MICRO))
    jax.block_until_ready(state.params)
    t0 = time.perf_counter()
    for i in range(1, STEPS + 1):
        batch = stack_microbatches(data.batch(i), N_MICRO)
        t_it = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        if managed:
            agent.heartbeat(now=time.time())
            agent.observe_iteration(time.perf_counter() - t_it)
            if i % 4 == 0:
                mgr.save(rank=0, step=i, state=state)
    dt = time.perf_counter() - t0
    return STEPS * BATCH / dt


def run() -> list:
    import tempfile
    rows = []
    for arch in ARCHS:
        with tempfile.TemporaryDirectory() as tmp:
            bare = _run_loop(False, arch, tmp)
            managed = _run_loop(True, arch, tmp)
        rows.append({"bench": "parity", "model": arch,
                     "bare_samples_s": bare, "unicron_samples_s": managed,
                     "parity": managed / bare})
    # Fig. 10b: achieved FLOP/s ratio at the paper's scale (cost model)
    for size in ["gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b",
                 "gpt3-175b"]:
        t = TaskModel.from_arch(get_arch(size), seq_len=2048,
                                global_batch=256)
        rows.append({"bench": "flops_ratio_64gpu", "model": size,
                     "bare_samples_s": 0.0, "unicron_samples_s": 0.0,
                     "parity": flops_ratio(t, 64, A800)})
    emit(rows, "throughput",
         ["bench", "model", "bare_samples_s", "unicron_samples_s", "parity"])
    return rows
