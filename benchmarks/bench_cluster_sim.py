"""Cluster-scale scenario-engine benchmark (§7.5 at production scale).

Runs the ``mixed_fleet`` scenario class (independent MTBF + correlated
switch-domain bursts + slow-node degradation + preemption waves + task
churn, ``core.scenarios``) through all three simulator engines:

* ``TraceSimulator`` — the per-event scalar reference loop (eager,
  uncached plan tables), timed on the fixed seed-0 scenario and
  extrapolated linearly over the seed batch (its cost per seed is
  independent: no state is shared between scalar runs);
* ``run_monte_carlo(engine="vector")`` — the PR-2/3 per-(policy, seed)
  engine over a shared ``PlannerCache``;
* ``run_monte_carlo(engine="batched")`` — the batched multi-policy
  engine: each seed runs ONCE with every policy stacked on the policy
  axis.

The batched-vs-vector comparison is measured at shared planner state
(both suites run against the same warmed ``PlannerCache``, min of two
passes): plan dispatch is state-keyed work whose decisions — and floats —
are identical in both engines, so the warm ratio isolates the per-policy
engine work the batched axis deduplicates (decode, detection/transition
arithmetic, bookkeeping, WAF accumulation); it is also the operating
regime of a fleet study sweeping policies over thousands of replays of a
standing scenario library.  The cold end-to-end walls are reported as
columns: cold runs are planner-dispatch-bound, and every simulator
dispatch materializes one plan, so both PlanTable engines pay the same
chain convolutions plus one traceback — ``cold_batched_wall_s`` (the
default level-synchronous batched planner engine) therefore tracks
``cold_segtree_wall_s`` (the PR-3 per-merge engine, identical seeds),
with ``cold_plan_speedup`` their ratio.  The batched engine's whole-table
replan win (O(log m) stacked launches, value-only assembly, traceback
only for the dispatched scenario) is measured in isolation by
``bench_planner_scale``'s whole-table churn axis.

Hard asserts, so the harness fails loudly on a regression:

* accumulated WAF of the vectorized engine matches the scalar reference
  loop to 1e-6 on the fixed-seed scenario, for every policy;
* accumulated WAF of the batched engine matches the scalar reference to
  1e-6 on the fixed seed-0 scenario, for every policy;
* at paper scale (n=1024 workers, m=32 tasks, 30-day trace, 16 seeds)
  the vector engine-suite speedup vs the scalar loop is >= 50x;
* at paper scale the batched suite is >= 3x faster than the vector
  suite at shared planner state.

``REPRO_BENCH_QUICK=1`` (set by ``run.py --quick``) runs only the small
configuration; the full run records both, so CI's quick output can be
gated against the committed baseline rows.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import emit, fleet_tasks
from repro.core import scenarios
from repro.core.planner import PlannerCache
from repro.core.simulator import TraceSimulator, run_monte_carlo

SPEEDUP_FLOOR = 50.0
BATCHED_FLOOR = 3.0
REL_TOL = 1e-6
GPN = 8

CONFIGS = [
    # name, n_nodes, m, span_days, seeds, mtbf_days, bursts, degr, waves,
    # assert_floor
    ("quick", 16, 6, 7, 4, 20, 1, 3, 1, False),
    ("paper_scale", 128, 32, 30, 16, 30, 3, 8, 2, True),
]


def _scenario_fn(n_nodes, m, span_days, mtbf_days, bursts, degr, waves,
                 tasks):
    def make(seed):
        return scenarios.mixed_fleet(
            n_nodes=n_nodes, span_s=span_days * scenarios.DAY, seed=seed,
            gpus_per_node=GPN, m_initial=m, candidates=tasks[:4],
            mtbf_node_s=mtbf_days * scenarios.DAY, group_size=8,
            n_bursts=bursts, n_degradations=degr, n_waves=waves,
            wave_fraction=0.1)
    return make


def _suite_wall(mc) -> float:
    return sum(r.wall_s for r in mc.values())


def run() -> list:
    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    configs = [c for c in CONFIGS if c[0] == "quick"] if quick else CONFIGS
    rows = []
    for (name, n_nodes, m, span_days, seeds, mtbf_days, bursts, degr,
         waves, assert_floor) in configs:
        tasks = fleet_tasks(m)
        per = (n_nodes * GPN // m) // GPN * GPN
        assignment = [per] * m
        make = _scenario_fn(n_nodes, m, span_days, mtbf_days, bursts,
                            waves=waves, degr=degr, tasks=tasks)
        s0 = make(0)

        cache = PlannerCache()
        mc = run_monte_carlo(tasks, assignment, make, seeds=range(seeds),
                             n_nodes=n_nodes, gpus_per_node=GPN,
                             plan_cache=cache, engine="vector")
        vec_total = _suite_wall(mc)

        # batched engine over the same warmed planner state; a second
        # warm vector pass is its like-for-like baseline (min of 2 per
        # engine: suite walls on small hosts are noisy)
        warm_vec = min(_suite_wall(run_monte_carlo(
            tasks, assignment, make, seeds=range(seeds), n_nodes=n_nodes,
            gpus_per_node=GPN, plan_cache=cache, engine="vector"))
            for _ in range(2))
        mcb = None
        bat_walls = []
        for _ in range(2):
            mcb = run_monte_carlo(tasks, assignment, make,
                                  seeds=range(seeds), n_nodes=n_nodes,
                                  gpus_per_node=GPN, plan_cache=cache,
                                  engine="batched")
            bat_walls.append(_suite_wall(mcb))
        bat_total = min(bat_walls)
        # cold end-to-end batched walls (fresh planner state; min of 2 —
        # same noise treatment as the warm walls).  The cold path is
        # planner-dispatch-bound: every event materializes one plan, so
        # the engines' per-dispatch work (chain convolutions + one
        # traceback) is the wall and the default batched planner engine
        # tracks the PR-3 segtree engine (its whole-table replan win —
        # O(log m) stacked launches, value-only, traceback only for the
        # dispatched scenario — is measured by ``bench_planner_scale``'s
        # whole-table churn axis).
        cold_bat = min(_suite_wall(run_monte_carlo(
            tasks, assignment, make, seeds=range(seeds), n_nodes=n_nodes,
            gpus_per_node=GPN, plan_cache=PlannerCache(),
            engine="batched")) for _ in range(2))
        cold_seg = min(_suite_wall(run_monte_carlo(
            tasks, assignment, make, seeds=range(seeds), n_nodes=n_nodes,
            gpus_per_node=GPN, plan_cache=PlannerCache(),
            engine="batched", plan_engine="segtree")) for _ in range(2))
        cold_plan_speedup = cold_seg / cold_bat

        scalar_total = 0.0
        scalar_s, rel_errs, bat_rel_errs = {}, {}, {}
        for policy, r in mc.items():
            t0 = time.perf_counter()
            # the scalar loop is pinned to the PR-4 planner configuration
            # (per-merge segtree tables): it is the preserved wall-clock
            # baseline the committed suite_speedup rows were measured
            # against.  Letting it ride the batched engine default would
            # HALVE its eager whole-table rebuild walls (~44s -> ~22s per
            # paper-scale seed on the recording machine) and silently
            # deflate every vector-vs-scalar ratio.
            ref = TraceSimulator(tasks, list(assignment), policy,
                                 n_nodes=n_nodes, gpus_per_node=GPN,
                                 plan_engine="segtree").run(s0)
            scalar_s[policy] = time.perf_counter() - t0
            scalar_total += scalar_s[policy]
            rel = (abs(ref.accumulated_waf - r.per_seed[0])
                   / max(abs(ref.accumulated_waf), 1.0))
            rel_errs[policy] = rel
            assert rel < REL_TOL, (name, policy, rel)
            brel = (abs(ref.accumulated_waf - mcb[policy].per_seed[0])
                    / max(abs(ref.accumulated_waf), 1.0))
            bat_rel_errs[policy] = brel
            assert brel < REL_TOL, (name, "batched", policy, brel)

        suite_speedup = scalar_total * seeds / vec_total
        batched_speedup = warm_vec / bat_total
        if assert_floor:
            assert suite_speedup >= SPEEDUP_FLOOR, (
                f"engine speedup {suite_speedup:.0f}x at {name} below the "
                f"{SPEEDUP_FLOOR:.0f}x floor")
            assert batched_speedup >= BATCHED_FLOOR, (
                f"batched engine {batched_speedup:.2f}x vs the vector "
                f"suite at {name} below the {BATCHED_FLOOR:.0f}x floor")
            print(f"[floor check] {name} (n={n_nodes * GPN}, m={m}, "
                  f"{seeds} seeds): vector {suite_speedup:.0f}x vs scalar "
                  f"(floor {SPEEDUP_FLOOR:.0f}x), batched "
                  f"{batched_speedup:.1f}x vs vector "
                  f"(floor {BATCHED_FLOOR:.0f}x)")
        for policy, r in mc.items():
            rows.append({
                "config": name, "policy": policy, "engine": "vector",
                "workers": n_nodes * GPN, "tasks": m, "seeds": seeds,
                "events": s0.n_events,
                "vec_wall_s": r.wall_s,
                "vec_per_seed_ms": r.wall_s / seeds * 1e3,
                "scalar_seed_s": scalar_s[policy],
                "waf_mean": r.waf_mean,
                "waf_rel_err": rel_errs[policy],
                "suite_speedup": suite_speedup,
            })
        for policy, r in mcb.items():
            rows.append({
                "config": name, "policy": policy, "engine": "batched",
                "workers": n_nodes * GPN, "tasks": m, "seeds": seeds,
                "events": s0.n_events,
                "batched_wall_s": r.wall_s,
                "warm_vector_wall_s": warm_vec / len(mc),
                "cold_batched_wall_s": cold_bat / len(mc),
                "cold_segtree_wall_s": cold_seg / len(mc),
                "cold_plan_speedup": cold_plan_speedup,
                "waf_mean": r.waf_mean,
                "waf_rel_err": bat_rel_errs[policy],
                "batched_speedup": batched_speedup,
            })
    emit(rows, "cluster_sim",
         ["config", "policy", "engine", "workers", "tasks", "seeds",
          "events", "vec_wall_s", "vec_per_seed_ms", "scalar_seed_s",
          "batched_wall_s", "warm_vector_wall_s", "cold_batched_wall_s",
          "cold_segtree_wall_s", "cold_plan_speedup",
          "waf_mean", "waf_rel_err", "suite_speedup", "batched_speedup"])
    return rows
