"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def emit(rows: List[Dict], name: str, columns: List[str]) -> None:
    """Print a CSV block and persist JSON under results/."""
    print(f"\n== {name} ==")
    print(",".join(columns))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in columns))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"bench_{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def _fmt(v) -> str:
    if v is None:                # skipped metric: null in JSON, blank in CSV
        return ""
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3,
           number: int = 1) -> float:
    """Median wall seconds per call.

    ``number``: calls per timed sample (timeit-style inner loop) — for
    ns-scale hot paths a single call is all clock noise, so batch >= 10k
    calls per sample and report the per-call average of the median
    sample."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        for _ in range(number):
            fn(*args)
        ts.append((time.perf_counter() - t0) / number)
    ts.sort()
    return ts[len(ts) // 2]


def case5_tasks():
    """Table 3 Case #5: the workload of the Fig. 11 trace experiments."""
    from repro.configs import get_arch
    from repro.core.costmodel import TaskModel
    from repro.core.waf import Task
    sizes = ["gpt3-1.3b"] * 3 + ["gpt3-7b"] * 2 + ["gpt3-13b"]
    weights = [2.0, 1.7, 1.4, 1.1, 0.8, 0.5]
    tasks = [Task(model=TaskModel.from_arch(get_arch(s), global_batch=128),
                  weight=w) for s, w in zip(sizes, weights)]
    assignment = [16, 16, 16, 24, 24, 32]
    return tasks, assignment


FLEET_SIZES = ["gpt3-1.3b", "gpt3-7b", "gpt3-13b", "gpt3-70b"]


def fleet_tasks(m: int, max_workers=None):
    """m heterogeneous tasks cycling the GPT-3 family with varied weights
    and batch sizes — the multi-task fleet shared by all cluster benches.

    ``max_workers``: per-task worker cap (``Task.max_workers``) applied to
    every task — the cap-aware fleets the banded planner kernels exploit.
    ``None`` keeps the historical uncapped fleet."""
    from repro.configs import get_arch
    from repro.core.costmodel import TaskModel
    from repro.core.waf import Task
    return [Task(model=TaskModel.from_arch(
                     get_arch(FLEET_SIZES[i % len(FLEET_SIZES)]),
                     global_batch=128 if i % 2 else 256),
                 weight=0.5 + 0.1 * (i % 16),
                 max_workers=max_workers) for i in range(m)]
