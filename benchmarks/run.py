"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run detection  # one
    python benchmarks/run.py --quick                   # CI smoke subset
    python benchmarks/run.py --only planner_scale      # one, full grid

``--quick`` sets REPRO_BENCH_QUICK=1 (benches trim their grids) and runs
the smoke subset unless specific benches are named.

``--only <bench>`` (repeatable; ``--only=<bench>`` also accepted) names
a single bench the same way a positional name does — use it to
re-record one baseline after a model change that only moves that
bench's rows, e.g. ``python benchmarks/run.py --only maxplus`` after a
kernel change, instead of regenerating the whole ``results/`` suite.
Baselines land wherever ``REPRO_RESULTS`` points (default
``results/``); commit the refreshed JSON so the CI regression gate
(``benchmarks/check_regression.py``) compares against it.
"""
from __future__ import annotations

import os
import sys
import time

# allow `python benchmarks/run.py` from a bare checkout: put the repo root
# (for the `benchmarks` package) and src/ (for `repro`) on the path
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

BENCHES = ["detection", "costmodel", "maxplus", "planner_scale",
           "cluster_sim", "serving_slo", "transition", "frontier",
           "throughput", "waf_multitask", "traces", "ablation",
           "roofline", "chaos", "controlplane"]
QUICK_BENCHES = ["detection", "costmodel", "maxplus", "planner_scale",
                 "cluster_sim", "serving_slo", "transition", "frontier",
                 "chaos", "controlplane"]


def main() -> None:
    args = sys.argv[1:]
    quick = "--quick" in args
    names, only, expect_only = [], [], False
    unknown = []
    for a in args:
        if expect_only:
            only.append(a)
            expect_only = False
        elif a == "--only":
            expect_only = True
        elif a.startswith("--only="):
            only.append(a.split("=", 1)[1])
        elif a == "--quick":
            pass
        elif a.startswith("--"):
            unknown.append(a)
        else:
            names.append(a)
    if expect_only:
        sys.exit("--only needs a bench name (e.g. --only planner_scale)")
    if unknown:
        sys.exit(f"unknown flags: {unknown} "
                 f"(supported: --quick, --only <bench>)")
    bad = [b for b in names + only if b not in BENCHES]
    if bad:
        sys.exit(f"unknown benches: {bad} (choose from {BENCHES})")
    names += only
    if quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"
    if not names:
        names = QUICK_BENCHES if quick else BENCHES
    failures = []
    for name in names:
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
            mod.run()
            print(f"[bench_{name}: ok, {time.perf_counter() - t0:.1f}s]")
        except Exception as e:                          # noqa: BLE001
            failures.append(name)
            print(f"[bench_{name}: FAILED — {e!r}]")
    if failures:
        sys.exit(f"failed benches: {failures}")


if __name__ == "__main__":
    main()
