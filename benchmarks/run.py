"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run detection  # one
"""
from __future__ import annotations

import sys
import time

BENCHES = ["detection", "costmodel", "transition", "throughput",
           "waf_multitask", "traces", "ablation", "roofline"]


def main() -> None:
    names = sys.argv[1:] or BENCHES
    failures = []
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            mod.run()
            print(f"[bench_{name}: ok, {time.perf_counter() - t0:.1f}s]")
        except Exception as e:                          # noqa: BLE001
            failures.append(name)
            print(f"[bench_{name}: FAILED — {e!r}]")
    if failures:
        sys.exit(f"failed benches: {failures}")


if __name__ == "__main__":
    main()
