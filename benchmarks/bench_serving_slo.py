"""Mixed training+serving fleet recovery under a diurnal request trace.

The headline artifact of the pluggable-objective redesign: a fleet mixing
training tasks (``TrainingWAF``, the paper's §5 reward) with serving
tasks (``ServingSLO``: goodput under a p99 latency SLO) runs through the
self-healing loop under injected failures while the serving tasks' offered
load follows a diurnal day/night cycle with traffic spikes
(``scenarios.diurnal_load`` / ``traffic_spikes`` rate events).

A serving task's ``weight`` is the exchange rate between goodput and
training throughput — FLOP-equivalents per served request — so the
knapsack DP (Eq. 5) trades the two currencies directly.  Because the SLO
curve *saturates* at the offered rate while the training curve keeps
climbing, the mixed-objective planner parks a serving task at its
saturation width and hands the remainder to training; a WAF-only planner
(same tasks, objectives forced to ``TrainingWAF``) keeps feeding the
high-weight task to its cap.  That divergence after an injected failure
is the measured trade-off.

Hard asserts (the harness fails loudly on a regression):

* accumulated WAF of the vector and batched engines matches the scalar
  reference loop to 1e-6 on the mixed fleet + rate-event trace, for
  every policy — rate epochs integrate identically across engines;
* after the injected failure, the mixed-objective plan DIFFERS from the
  WAF-only plan (>= 1 slot), serves >= 90% of its goodput, and strictly
  beats its training WAF — the planner measurably trades training
  throughput against serving goodput;
* all planner engines (batched / segtree / chain PlanTable scenarios and
  ``solve`` / ``solve_reference``) agree on the mixed-fleet fault plan's
  total reward to 1e-6 and on its assignment exactly.

``REPRO_BENCH_QUICK=1`` (set by ``run.py --quick``) runs only the small
configuration; the full run records both.
"""
from __future__ import annotations

import dataclasses
import os
import time

from benchmarks.common import emit, fleet_tasks
from repro.core import planner, scenarios, waf as waf_mod
from repro.core.costmodel import A800
from repro.core.planner import PlanInput, PlanTable
from repro.core.simulator import BatchSimulator, TraceSimulator, \
    VectorSimulator
from repro.core.waf import TRAINING_WAF, ServingSLO, Task

REL_TOL = 1e-6
GPN = 8
#: goodput <-> training-throughput exchange rate (FLOP-equivalents per
#: served request) — sized so serving dominates training's marginal
#: FLOP/s until the SLO curve saturates (past saturation the exponential
#: tail decays below any training marginal, so the planner hands the
#: remaining workers to training; a WAF-only planner keeps feeding the
#: high-weight slot to its cap).
SERVING_WEIGHT = 1e14
POLICIES = ("unicron", "megatron")

CONFIGS = [
    # name, n_nodes, m_train, m_serve, span_days, mtbf_days
    ("quick", 16, 4, 2, 3, 10),
    ("full", 64, 12, 4, 7, 15),
]


def _mixed_fleet(m_train: int, m_serve: int):
    """m_train training tasks + m_serve capped serving tasks (distinct
    offered rates so the saturation widths differ per task)."""
    train = fleet_tasks(m_train)
    serving = []
    for k in range(m_serve):
        slo = ServingSLO(rate_rps=120.0 + 40.0 * k, capacity_rps=8.0)
        serving.append(Task(model=train[k % m_train].model,
                            weight=SERVING_WEIGHT, max_workers=40,
                            objective=slo))
    return train + serving


def _assignment(tasks, n_total: int, m_serve: int):
    """Node-granular initial split: each serving task starts at 24
    workers (near saturation), training splits the remainder."""
    m_train = len(tasks) - m_serve
    serve_w = [24] * m_serve
    per = (n_total - sum(serve_w)) // m_train // GPN * GPN
    return [per] * m_train + serve_w


def _serving_trace(n_nodes, span_s, seed, tasks, m_serve, mtbf_days):
    """Injected failures + one diurnal cycle and one spike train per
    serving slot."""
    out = scenarios.independent_failures(
        n_nodes=n_nodes, span_s=span_s, seed=seed, gpus_per_node=GPN,
        mtbf_node_s=mtbf_days * scenarios.DAY)
    m = len(tasks)
    for k in range(m_serve):
        slot = m - m_serve + k
        base = tasks[slot].objective
        out = out.merged(scenarios.diurnal_load(
            n_nodes=n_nodes, span_s=span_s, seed=seed * 7 + k, slot=slot,
            base=base, gpus_per_node=GPN))
        out = out.merged(scenarios.traffic_spikes(
            n_nodes=n_nodes, span_s=span_s, seed=seed * 11 + k, slot=slot,
            base=base, gpus_per_node=GPN))
    out.name = "serving_slo"
    return out


def _goodput_rps(tasks, assignment, m_serve) -> float:
    """Raw served requests/s (weight divided back out) at an assignment."""
    total = 0.0
    for t, x in zip(tasks[-m_serve:], assignment[-m_serve:]):
        total += waf_mod.waf(t, int(x), A800) / t.weight
    return total


def _train_waf(tasks, assignment, m_serve) -> float:
    m_train = len(tasks) - m_serve
    return sum(waf_mod.waf(t, int(x), A800)
               for t, x in zip(tasks[:m_train], assignment[:m_train]))


def _tradeoff(tasks, assignment, n_total: int, m_serve: int):
    """Replan after an injected failure, with the real objectives vs all
    objectives forced to ``TrainingWAF``, and measure the divergence."""
    fault_slot = 0
    n_after = n_total - GPN                       # one node lost
    d_run = waf_mod.expected_run_duration(n_total, 30 * scenarios.DAY)
    d_trans = 120.0
    faulted = tuple(i == fault_slot for i in range(len(tasks)))
    inp = PlanInput(tuple(tasks), tuple(assignment), n_after,
                    d_run, d_trans, faulted)
    plan_mixed = planner.solve(inp, A800)
    waf_tasks = tuple(dataclasses.replace(t, objective=TRAINING_WAF)
                      for t in tasks)
    plan_wafonly = planner.solve(
        PlanInput(waf_tasks, tuple(assignment), n_after, d_run, d_trans,
                  faulted), A800)

    # planner-engine agreement on the mixed-fleet fault scenario: the
    # three PlanTable engines assemble the same plan, and the reference
    # DP agrees with the vectorized solver on the fresh dispatch
    ref = planner.solve_reference(inp, A800)
    engine_rel = abs(plan_mixed.total_reward - ref.total_reward) \
        / max(abs(ref.total_reward), 1.0)
    assert plan_mixed.assignment == ref.assignment, "solve != reference"
    table_plans = {}
    for eng in ("batched", "segtree", "chain"):
        table = PlanTable(tasks, assignment, A800, d_run, d_trans,
                          workers_per_fault=GPN, engine=eng,
                          n_budget=n_total + GPN)
        table_plans[eng] = table.lookup(f"fault:{fault_slot}")
    base = table_plans["batched"]
    for eng, p in table_plans.items():
        rel = abs(p.total_reward - base.total_reward) \
            / max(abs(base.total_reward), 1.0)
        engine_rel = max(engine_rel, rel)
        assert p.assignment == base.assignment, (eng, "assignment drift")
        assert rel < REL_TOL, (eng, rel)

    diff = sum(a != b for a, b in zip(plan_mixed.assignment,
                                      plan_wafonly.assignment))
    gp_mixed = _goodput_rps(tasks, plan_mixed.assignment, m_serve)
    gp_wafonly = _goodput_rps(tasks, plan_wafonly.assignment, m_serve)
    tw_mixed = _train_waf(tasks, plan_mixed.assignment, m_serve)
    tw_wafonly = _train_waf(tasks, plan_wafonly.assignment, m_serve)
    assert diff >= 1, "mixed-objective plan identical to WAF-only plan"
    assert gp_mixed >= 0.9 * gp_wafonly, (gp_mixed, gp_wafonly)
    assert tw_mixed > tw_wafonly, (tw_mixed, tw_wafonly)
    return {
        "plan_diff_slots": diff,
        "goodput_mixed_rps": gp_mixed,
        "goodput_wafonly_rps": gp_wafonly,
        "train_waf_mixed": tw_mixed,
        "train_waf_wafonly": tw_wafonly,
        "engine_rel_err": engine_rel,
    }


def run() -> list:
    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    configs = [c for c in CONFIGS if c[0] == "quick"] if quick else CONFIGS
    rows = []
    for name, n_nodes, m_train, m_serve, span_days, mtbf_days in configs:
        n_total = n_nodes * GPN
        tasks = _mixed_fleet(m_train, m_serve)
        assignment = _assignment(tasks, n_total, m_serve)
        trace = _serving_trace(n_nodes, span_days * scenarios.DAY, 3,
                               tasks, m_serve, mtbf_days)

        for policy in POLICIES:
            t0 = time.perf_counter()
            ref = TraceSimulator(tasks, list(assignment), policy,
                                 n_nodes=n_nodes, gpus_per_node=GPN
                                 ).run(trace)
            scalar_wall = time.perf_counter() - t0
            vec = VectorSimulator(tasks, list(assignment), policy,
                                  n_nodes=n_nodes, gpus_per_node=GPN
                                  ).run(trace)
            vrel = abs(ref.accumulated_waf - vec.accumulated_waf) \
                / max(abs(ref.accumulated_waf), 1.0)
            assert vrel < REL_TOL, (name, policy, "vector", vrel)
            rows.append({
                "config": name, "policy": policy,
                "workers": n_total, "tasks_train": m_train,
                "tasks_serve": m_serve, "events": trace.n_events,
                "scalar_waf": ref.accumulated_waf,
                "vector_rel_err": vrel,
                "scalar_wall_s": scalar_wall,
            })

        t0 = time.perf_counter()
        batch = BatchSimulator(tasks, list(assignment), list(POLICIES),
                               n_nodes=n_nodes, gpus_per_node=GPN
                               ).run(trace)
        batch_wall = time.perf_counter() - t0
        for row in rows:
            if row["config"] != name:
                continue
            bres = batch[row["policy"]]
            brel = abs(row["scalar_waf"] - bres.accumulated_waf) \
                / max(abs(row["scalar_waf"]), 1.0)
            assert brel < REL_TOL, (name, row["policy"], "batched", brel)
            row["batched_rel_err"] = brel
            row["batched_wall_s"] = batch_wall / len(POLICIES)

        trade = _tradeoff(tasks, assignment, n_total, m_serve)
        rows.append({"config": name, "policy": "planner",
                     "workers": n_total, "tasks_train": m_train,
                     "tasks_serve": m_serve, "events": trace.n_events,
                     **trade})
        print(f"[tradeoff] {name}: plan differs on "
              f"{trade['plan_diff_slots']} slot(s); goodput "
              f"{trade['goodput_mixed_rps']:.1f} vs "
              f"{trade['goodput_wafonly_rps']:.1f} rps, training WAF "
              f"{trade['train_waf_mixed']:.3g} vs "
              f"{trade['train_waf_wafonly']:.3g}")
    emit(rows, "serving_slo",
         ["config", "policy", "workers", "tasks_train", "tasks_serve",
          "events", "scalar_waf", "vector_rel_err", "batched_rel_err",
          "scalar_wall_s", "batched_wall_s", "plan_diff_slots",
          "goodput_mixed_rps", "goodput_wafonly_rps", "train_waf_mixed",
          "train_waf_wafonly", "engine_rel_err"])
    return rows
