"""Figure-11-style cost/recovery frontier over calibrated traces.

Sweeps all eight recovery policies — the paper's five (unicron,
megatron, oobleck, bamboo, varuna) plus the ISSUE-10 recovery-frontier
policies (fftrainer hot-spare failover, hierarchical_ckpt tiered
restore, redundant continuation) — over ``scenarios.calibrated_fleet``
traces (per-category rates from the Acme/Meta characterizations,
``core/calibration.py``) and places each on the (downtime, WAF) plane:

* cost axis — accumulated blocked task-seconds (``downtime_s``);
* value axis — mean accumulated WAF over the seed batch (``waf_mean``).

``on_frontier`` marks policies not weakly dominated by any other of the
eight; ``beyond_paper`` marks a NEW policy no paper policy weakly
dominates (lower-or-equal downtime AND higher-or-equal WAF) — the
point the paper's five cannot reach.  The bench asserts each new policy
is beyond the paper frontier in at least one configuration:

* ``quick`` — 16 nodes / 6 tasks / 7 days at 8x intensity (the CI
  configuration): all three new policies sit beyond the paper five.
* ``calibrated_30d`` — the headline (n=1024 workers, m=32) 30-day trace
  at the committed default rates: fftrainer and redundant are beyond
  the paper frontier; hierarchical_ckpt is honestly dominated by
  unicron here — with DP degree 4 the nearest principle restores from a
  DP replica at 150 GB/s, cheaper than the in-memory ring at 25 GB/s,
  which is precisely the paper's §6.3 argument.
* ``straggler_30d`` — same (n=1024, m=32) scale on a straggler-heavy
  fleet (8x the calibrated slow-node rate; Acme reports degradation
  anomaly rates varying widely across clusters): unicron's drain
  transitions now dominate its downtime, and hierarchical_ckpt's
  crawl-through-degradation point moves beyond all five.

Every policy's batched-engine WAF is asserted against the scalar
``TraceSimulator`` reference on the seed-0 scenario to 1e-6 (the
scalar runs share the warmed ``PlannerCache``: decisions are identical
and this bench gates model output, not planner walls —
``bench_cluster_sim`` owns the timing baselines).

``REPRO_BENCH_QUICK=1`` runs only the quick configuration; the gate in
``check_regression`` pins its per-policy ``waf_mean``, ``downtime_s``
and frontier booleans.
"""
from __future__ import annotations

import dataclasses
import os
import time

from benchmarks.common import emit, fleet_tasks
from repro.core import scenarios
from repro.core.calibration import DAY, DEFAULT_CALIBRATION
from repro.core.planner import PlannerCache
from repro.core.simulator import EFFICIENCY, TraceSimulator, run_monte_carlo

REL_TOL = 1e-6
GPN = 8
PAPER_POLICIES = ("unicron", "megatron", "oobleck", "bamboo", "varuna")
NEW_POLICIES = ("fftrainer", "hierarchical_ckpt", "redundant")

CONFIGS = [
    # name, n_nodes, m, span_days, seeds, intensity, slow_boost
    ("quick", 16, 6, 7, 2, 8.0, 1.0),
    ("calibrated_30d", 128, 32, 30, 4, 1.0, 1.0),
    ("straggler_30d", 128, 32, 30, 4, 1.0, 8.0),
]


def _calibration(slow_boost: float):
    if slow_boost == 1.0:
        return DEFAULT_CALIBRATION
    return dataclasses.replace(
        DEFAULT_CALIBRATION,
        slow_rate_per_node_s=(DEFAULT_CALIBRATION.slow_rate_per_node_s
                              * slow_boost))


def _scenario_fn(n_nodes, m, span_days, intensity, calib, tasks):
    def make(seed):
        return scenarios.calibrated_fleet(
            n_nodes=n_nodes, span_s=span_days * DAY, seed=seed,
            gpus_per_node=GPN, m_initial=m, candidates=tasks[:4],
            calib=calib, intensity=intensity)
    return make


def _weakly_dominates(a, b) -> bool:
    """``a`` is at least as good as ``b`` on both axes."""
    return (a.downtime_s <= b.downtime_s + 1e-9
            and a.waf_mean >= b.waf_mean - 1e-9)


def run() -> list:
    quick = os.environ.get("REPRO_BENCH_QUICK") == "1"
    configs = [c for c in CONFIGS if c[0] == "quick"] if quick else CONFIGS
    policies = list(EFFICIENCY)
    rows = []
    beyond_any = {p: False for p in NEW_POLICIES}
    for (name, n_nodes, m, span_days, seeds, intensity,
         slow_boost) in configs:
        tasks = fleet_tasks(m)
        per = (n_nodes * GPN // m) // GPN * GPN
        assignment = [per] * m
        calib = _calibration(slow_boost)
        make = _scenario_fn(n_nodes, m, span_days, intensity, calib, tasks)
        s0 = make(0)

        cache = PlannerCache()
        t0 = time.perf_counter()
        mc = run_monte_carlo(tasks, assignment, make, seeds=range(seeds),
                             n_nodes=n_nodes, gpus_per_node=GPN,
                             plan_cache=cache, engine="batched")
        wall = time.perf_counter() - t0

        rel_errs = {}
        for policy in policies:
            ref = TraceSimulator(tasks, list(assignment), policy,
                                 n_nodes=n_nodes, gpus_per_node=GPN,
                                 plan_cache=cache).run(s0)
            rel = (abs(ref.accumulated_waf - mc[policy].per_seed[0])
                   / max(abs(ref.accumulated_waf), 1.0))
            rel_errs[policy] = rel
            assert rel < REL_TOL, (name, policy, rel)

        for policy in policies:
            r = mc[policy]
            on_frontier = not any(
                _weakly_dominates(mc[o], r) for o in policies
                if o != policy and not _weakly_dominates(r, mc[o]))
            beyond = (policy in NEW_POLICIES and not any(
                _weakly_dominates(mc[o], r) for o in PAPER_POLICIES))
            if beyond:
                beyond_any[policy] = True
            rows.append({
                "config": name, "policy": policy,
                "workers": n_nodes * GPN, "tasks": m, "seeds": seeds,
                "events": s0.n_events,
                "waf_mean": r.waf_mean,
                "downtime_s": r.downtime_s,
                "n_reconfigs": r.n_reconfigs,
                "on_frontier": on_frontier,
                "beyond_paper": beyond,
                "waf_rel_err": rel_errs[policy],
                "wall_s": wall,
            })
        frontier = [p for p in policies
                    if [row for row in rows
                        if row["config"] == name and row["policy"] == p
                        and row["on_frontier"]]]
        print(f"[frontier] {name} (n={n_nodes * GPN}, m={m}): "
              f"frontier={frontier}, beyond_paper="
              f"{[p for p in NEW_POLICIES if beyond_any[p]]}")
    for policy in NEW_POLICIES:
        assert beyond_any[policy], (
            f"{policy} never beyond the paper-five frontier in "
            f"{[c[0] for c in configs]}")
    emit(rows, "frontier",
         ["config", "policy", "workers", "tasks", "seeds", "events",
          "waf_mean", "downtime_s", "n_reconfigs", "on_frontier",
          "beyond_paper", "waf_rel_err", "wall_s"])
    return rows
