"""Chaos-hardened control plane (ISSUE 6): per-class time-to-reconverge
of the agent -> status-monitor -> control-loop path under seeded fault
injection, plus the coordinator-journaling overhead on the dispatch path.

Gated rows (``check_regression.py``):

* one row per ``scenarios.chaos_suite`` class — ``converged`` (1.0),
  ``waf_delta`` vs the chaos-free run (0 within 1e-6), and the
  deterministic ``reconverge_s`` (how long after the last world event
  the control plane kept reacting to chaos) are ``equal``-gated;
* the journaling overhead ratios are ``lower``-gated: journal writes sit
  outside the timed dispatch windows, so enabling the journal must stay
  well under 2x on both the end-to-end churn path and the measured
  ``last_dispatch_s`` fault path.
"""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.configs import get_arch
from repro.core.chaos import ChaosHarness, demo_world, world_windows
from repro.core.coordinator import UnicronCoordinator
from repro.core.costmodel import A800, TaskModel
from repro.core.handling import Trigger
from repro.core.scenarios import chaos_suite
from repro.core.waf import Task

SPAN = 2600.0
SUITE_SEED = 3
HARNESS_SEED = 7


def _fleet():
    def mk(size, w):
        return Task(model=TaskModel.from_arch(get_arch(size),
                                              global_batch=128), weight=w)
    tasks = [mk("gpt3-1.3b", 2.0), mk("gpt3-7b", 1.4), mk("gpt3-1.3b", 1.0)]
    return tasks, [8, 8, 4], mk("gpt3-1.3b", 0.7)


def _run_harness(world, schedule=None, seed=0):
    tasks, assignment, _ = _fleet()
    h = ChaosHarness(tasks=tasks, assignment=assignment, hw=A800,
                     schedule=schedule, seed=seed)
    until = SPAN if schedule is None else max(SPAN,
                                              schedule.horizon() + 120.0)
    return h, h.run(world, until=until)


def _reconvergence_rows():
    tasks, assignment, launch = _fleet()
    world = demo_world(tasks[2], launch)
    last_world_t = max(ev.time for ev in world)
    _, free = _run_harness(world)
    rows = []
    suite = chaos_suite(seed=SUITE_SEED, span_s=SPAN, n_nodes=6,
                        avoid=world_windows(world))
    for name, sched in suite.items():
        h, res = _run_harness(world, schedule=sched, seed=HARNESS_SEED)
        converged = (res.assignment == free.assignment
                     and abs(res.waf - free.waf) < 1e-6
                     and h.quiesced())
        assert converged, f"chaos class {name!r} failed to reconverge"
        rows.append({
            "case": name,
            "converged": float(converged),
            "waf_delta": abs(res.waf - free.waf),
            # how long past the last world event the control plane was
            # still reacting (restores, late deliveries, crash recovery)
            "reconverge_s": max(0.0, res.last_event_t - last_world_t),
            "n_crashes": res.n_crashes,
            "n_partitions": len(sched.partitions),
            "dropped": res.chaos_stats["dropped"],
            "delayed": res.chaos_stats["delayed"],
            "duplicated": res.chaos_stats["duplicated"],
            "rejected": res.chaos_stats["rejected"],
        })
    return rows


def _journal_overhead_row():
    def mk_coord(journal):
        tasks, assignment, _ = _fleet()
        return UnicronCoordinator(list(tasks), list(assignment), A800,
                                  n_cluster_workers=24, workers_per_node=4,
                                  journal=journal)

    _, _, launch = _fleet()

    def churn(coord):
        coord.task_launched(launch, 20, avg_iter_s=12.0)
        coord.task_finished(len(coord.entries) - 1, 24)

    def fault_dispatch(coord):
        coord.reconfigure(20, faulted_task=1)
        d = coord.plan_stats.last_dispatch_s
        coord.reconfigure(24, trigger=Trigger.NODE_JOIN)
        return d

    on, off = mk_coord(journal=True), mk_coord(journal=False)
    churn_on = timeit(churn, on, warmup=2, iters=7)
    churn_off = timeit(churn, off, warmup=2, iters=7)
    d_on = sorted(fault_dispatch(on) for _ in range(15))[7]
    d_off = sorted(fault_dispatch(off) for _ in range(15))[7]
    churn_ratio = churn_on / max(churn_off, 1e-12)
    dispatch_ratio = d_on / max(d_off, 1e-12)
    # the design claim: journal writes live outside the timed windows
    assert churn_ratio < 2.0, churn_ratio
    assert dispatch_ratio < 2.0, dispatch_ratio
    return {
        "case": "journal_overhead",
        "churn_on_s": churn_on, "churn_off_s": churn_off,
        "churn_overhead_ratio": churn_ratio,
        "dispatch_on_s": d_on, "dispatch_off_s": d_off,
        "dispatch_overhead_ratio": dispatch_ratio,
    }


def run() -> list:
    rows = _reconvergence_rows()
    rows.append(_journal_overhead_row())
    emit(rows, "chaos",
         ["case", "converged", "waf_delta", "reconverge_s", "n_crashes",
          "n_partitions", "dropped", "delayed", "duplicated", "rejected",
          "churn_overhead_ratio", "dispatch_overhead_ratio",
          "churn_on_s", "churn_off_s", "dispatch_on_s", "dispatch_off_s"])
    return rows


if __name__ == "__main__":
    run()
