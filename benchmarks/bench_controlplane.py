"""Fleet-scale control plane (ISSUE 9): sustained event ingestion and
event->plan-dispatch latency at n >= 100k simulated agents.

Two stacks, identical semantics (the equivalence suite in
``tests/test_controlplane.py`` proves byte-equal event streams):

* ``legacy``  — ``LegacyKVStore``: per-key heartbeat puts, per-lease
  Python expiry, scan+sort+delete drains — O(store) per tick;
* ``sharded`` — ``KVStore``: one ``heartbeat_batch`` array scatter per
  cohort, vectorized lease expiry, queue-cursor drains — O(events).

The ingestion phase drives T ticks of (100k heartbeats + E immediate
SEV3 error reports) through ``ControlLoop.tick`` and asserts the
sharded path sustains **>= 20x** the legacy events/sec (in-bench floor;
the ratio is also ``higher``-gated by ``check_regression.py``, and the
deterministic event counts are ``equal``-gated).  The dispatch phase
(sharded stack) injects SEV1 faults on assigned nodes and reports
p50/p99 event->plan-dispatch latency — the full drain+replan+assign
path at fleet scale.
"""
from __future__ import annotations

import gc
import os
import time
from contextlib import contextmanager

import numpy as np

from benchmarks.common import emit, fleet_tasks
from repro.core.cluster import Cluster
from repro.core.controlloop import ControlLoop
from repro.core.coordinator import UnicronCoordinator
from repro.core.costmodel import A800
from repro.core.detection import ErrorKind
from repro.core.handling import Action
from repro.core.kvstore import KVStore, LegacyKVStore
from repro.core.planner import PlannerCache

QUICK = bool(os.environ.get("REPRO_BENCH_QUICK"))

N_AGENTS = 100_000
M_TASKS = 8
CAP = 64                      # per-task worker cap: banded planner kernels
HB_TTL = 6.0
TICK_S = 2.0
FLOOR = 20.0                  # asserted ingestion-throughput speedup

# "quick" rows are always emitted (they key the CI regression gate);
# the "full" config only runs outside --quick
CONFIGS = {
    "quick": dict(errors=64, ticks_sharded=24, ticks_legacy=3, faults=4),
    "full": dict(errors=128, ticks_sharded=80, ticks_legacy=5, faults=10),
}


@contextmanager
def _gc_paused():
    """Collect once, then keep the cyclic GC out of the timed windows.

    Earlier benches in a ``run.py`` sweep leave large live heaps (jit
    traces, result rows); gen-0 pauses amortized over those dwarf a
    millisecond-scale sharded tick while vanishing inside a 100ms
    legacy scan — pausing GC symmetrically keeps the ratio honest."""
    gc.collect()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was_enabled:
            gc.enable()


def _stack(kv_cls):
    tasks = fleet_tasks(M_TASKS, max_workers=CAP)
    assignment = [CAP] * M_TASKS
    kv = kv_cls()
    coord = UnicronCoordinator(tasks, assignment, A800, kv=kv,
                               plan_cache=PlannerCache(),
                               n_cluster_workers=N_AGENTS,
                               workers_per_node=1)
    cluster = Cluster(N_AGENTS, gpus_per_node=1)
    cluster.assign(assignment)
    # no per-agent Python objects: the bench drives the store directly
    # (heartbeats + crafted reports), which is the ingestion path itself
    loop = ControlLoop(coord, cluster, {})
    return kv, coord, cluster, loop


def _beat_all(kv, legacy, t):
    if not legacy:
        kv.heartbeat_batch(_beat_all.ids, t, ttl=HB_TTL)
    else:
        # per-agent producers format their own key on every beat
        for i in range(N_AGENTS):
            kv.put(f"/nodes/{i}/alive", t, ttl=HB_TTL, now=t)


_beat_all.ids = np.arange(N_AGENTS)


def _inject_errors(kv, t, count, seq):
    """``count`` immediately-visible SEV3 reports, nodes spread over the
    whole id space (exercises bucket routing)."""
    kind = ErrorKind.CONNECTION_REFUSED.value
    for j in range(count):
        node = ((seq + j) * 997) % N_AGENTS
        kv.put(f"/errors/{node}/{t:.3f}", {
            "node": node, "kind": kind, "severity": 3,
            "method": "process_supervision", "raised_at": t,
            "visible_at": t}, now=t)
    return count


def _ingestion_row(config, store_name, kv_cls, cfg):
    kv, coord, cluster, loop = _stack(kv_cls)
    legacy = kv_cls is LegacyKVStore
    ticks = cfg[f"ticks_{store_name}"]
    errors = cfg["errors"]
    seq = 0
    # warmup: populate leases, run the first-tick GC, prime the planner
    for w in range(2):
        t = TICK_S * w
        _beat_all(kv, legacy, t)
        loop.tick(t)
    fired = 0
    with _gc_paused():
        t0 = time.perf_counter()
        for i in range(ticks):
            t = TICK_S * (2 + i)
            _beat_all(kv, legacy, t)
            seq += _inject_errors(kv, t, errors, seq)
            fired += len(loop.tick(t))
        wall = time.perf_counter() - t0
    events = (N_AGENTS + errors) * ticks
    assert fired == errors * ticks, (fired, errors * ticks)
    assert all(e.action is Action.REATTEMPT for e in loop.events[-fired:])
    return {
        "config": config, "store": store_name, "agents": N_AGENTS,
        "ticks": ticks, "events_per_tick": N_AGENTS + errors,
        "events": events, "loop_events": fired,
        "wall_s": wall, "events_per_sec": events / wall,
    }, (kv, coord, cluster, loop)


def _dispatch_latency(stack, cfg, t_start):
    """SEV1 faults on assigned nodes: per-event wall from visible report
    to dispatched plan + cluster reassignment (one tick each)."""
    kv, coord, cluster, loop = stack
    samples, replans = [], 0
    t = t_start
    with _gc_paused():
        for k in range(cfg["faults"]):
            t += TICK_S
            kv.heartbeat_batch(_beat_all.ids, t, ttl=HB_TTL)
            node = k                            # nodes 0..511 are assigned
            kv.put(f"/errors/{node}/{t:.3f}", {
                "node": node, "kind": ErrorKind.ECC_ERROR.value,
                "severity": 1, "method": "exception_propagation",
                "raised_at": t, "visible_at": t}, now=t)
            t0 = time.perf_counter()
            evs = loop.tick(t)
            samples.append(time.perf_counter() - t0)
            assert len(evs) == 1 and evs[0].action is Action.RECONFIGURE
            assert evs[0].plan is not None
            replans += 1
    ms = np.asarray(samples) * 1e3
    return {
        "p50_event_ms": float(np.percentile(ms, 50)),
        "p99_event_ms": float(np.percentile(ms, 99)),
        "sev1_replans": replans,
    }


def run() -> list:
    rows = []
    configs = ["quick"] if QUICK else ["quick", "full"]
    for config in configs:
        cfg = CONFIGS[config]
        legacy_row, _ = _ingestion_row(config, "legacy", LegacyKVStore, cfg)
        sharded_row, stack = _ingestion_row(config, "sharded", KVStore, cfg)
        speedup = (sharded_row["events_per_sec"]
                   / legacy_row["events_per_sec"])
        assert speedup >= FLOOR, (
            f"sharded ingestion {speedup:.1f}x < {FLOOR}x floor "
            f"({sharded_row['events_per_sec']:.3g} vs "
            f"{legacy_row['events_per_sec']:.3g} ev/s)")
        sharded_row["ingest_speedup"] = speedup
        t_start = TICK_S * (2 + sharded_row["ticks"])
        sharded_row.update(_dispatch_latency(stack, cfg, t_start))
        rows += [legacy_row, sharded_row]
        print(f"[{config}] n={N_AGENTS}: sharded "
              f"{sharded_row['events_per_sec']:.3g} ev/s vs legacy "
              f"{legacy_row['events_per_sec']:.3g} ev/s -> "
              f"{speedup:.1f}x; p99 dispatch "
              f"{sharded_row['p99_event_ms']:.1f} ms")
    emit(rows, "controlplane",
         ["config", "store", "agents", "ticks", "events_per_tick",
          "events", "loop_events", "wall_s", "events_per_sec",
          "ingest_speedup", "p50_event_ms", "p99_event_ms",
          "sev1_replans"])
    return rows


if __name__ == "__main__":
    run()
